"""Paper Fig. 15: edge-centric EdgeScan (edge lists) vs vertex-centric
EdgeMap (CSR) across input-set selectivities.  Reproduces the paper's
crossover: CSR wins at low selectivity (prunes whole adjacency ranges),
edge lists win at high selectivity (sequential scan locality)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, graph500_lake, make_engine, timed
from repro.core.baselines import CSRTopology, csr_edge_map, edge_list_edge_map


def run(scale: int = 14) -> None:
    store, schema = graph500_lake("fig15", scale)
    eng = make_engine(store, schema)
    eng.startup()
    src, dst = eng.concat_edges("Edge")
    n = eng.topology.n_vertices("Node")

    csr = CSRTopology(src, dst, n)
    el_build = eng.topology.timings.get(      # second connections load instead
        "edge_list_build_s", eng.topology.timings.get("load_topology_s", 0.0))
    emit("fig15_csr_build_us", csr.build_seconds * 1e6,
         f"edge_list_build_or_load={el_build*1e6:.0f}us")

    rng = np.random.default_rng(0)
    crossover = None
    prev = None
    for sel in (0.0001, 0.001, 0.01, 0.1, 0.5, 1.0):
        k = max(1, int(n * sel))
        active = rng.choice(n, size=k, replace=False)
        mask = np.zeros(n, dtype=bool)
        mask[active] = True

        _, t_csr = timed(csr_edge_map, csr, active, repeats=3)
        _, t_el = timed(edge_list_edge_map, src, dst, mask, repeats=3)
        emit(f"fig15_sel{sel}_csr_us", t_csr * 1e6, "")
        emit(f"fig15_sel{sel}_edgelist_us", t_el * 1e6,
             f"speedup_vs_csr={t_csr / t_el:.2f}x")
        if prev is not None and prev < 1.0 <= t_csr / t_el and crossover is None:
            crossover = sel
        prev = t_csr / t_el
    if crossover:
        emit("fig15_crossover_selectivity", crossover * 1e6, f"~{crossover}")
    eng.close()
