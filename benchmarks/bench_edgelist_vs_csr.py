"""Paper Fig. 15: edge-centric EdgeScan (edge lists) vs vertex-centric
EdgeMap (CSR) across input-set selectivities, plus the topology plane's
adaptive dispatcher on top of both.

Reproduces the paper's crossover — CSR wins at low selectivity (prunes whole
adjacency ranges), edge lists win at high selectivity (sequential scan
locality) — and then checks that ``edge_scan(strategy="auto")`` tracks the
faster representation on both sides of it.  The crossover selectivity
observed here calibrates ``DEFAULT_CSR_THRESHOLD`` in
``repro.core.topology_plane`` (override: ``REPRO_OPTS="csr=<threshold>"``).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, graph500_lake, make_engine, timed
from repro.core.baselines import CSRTopology, csr_edge_map, edge_list_edge_map
from repro.core.types import VSet

SELECTIVITIES = (0.0001, 0.001, 0.01, 0.1, 0.5, 1.0)
QUICK_SELECTIVITIES = (0.001, 0.5)


def run(scale: int = 14, quick: bool = False) -> None:
    if quick:
        scale = min(scale, 10)
    sels = QUICK_SELECTIVITIES if quick else SELECTIVITIES
    repeats = 1 if quick else 3

    store, schema = graph500_lake("fig15_q" if quick else "fig15", scale)
    eng = make_engine(store, schema)
    eng.startup()
    src, dst = eng.concat_edges("Edge")
    n = eng.topology.n_vertices("Node")

    # -- raw gather crossover (Fig. 15 proper) -------------------------------
    csr = CSRTopology(src, dst, n)
    el_build = eng.topology.timings.get(      # second connections load instead
        "edge_list_build_s", eng.topology.timings.get("load_topology_s", 0.0))
    emit("fig15_csr_build_us", csr.build_seconds * 1e6,
         f"edge_list_build_or_load={el_build*1e6:.0f}us")

    rng = np.random.default_rng(0)
    crossover = None
    prev = None
    frontiers = {}
    for sel in sels:
        k = max(1, int(n * sel))
        active = np.sort(rng.choice(n, size=k, replace=False))
        mask = np.zeros(n, dtype=bool)
        mask[active] = True
        frontiers[sel] = active

        _, t_csr = timed(csr_edge_map, csr, active, repeats=repeats)
        _, t_el = timed(edge_list_edge_map, src, dst, mask, repeats=repeats)
        emit(f"fig15_sel{sel}_csr_us", t_csr * 1e6, "")
        emit(f"fig15_sel{sel}_edgelist_us", t_el * 1e6,
             f"speedup_vs_csr={t_csr / t_el:.2f}x")
        if prev is not None and prev < 1.0 <= t_csr / t_el and crossover is None:
            crossover = sel
        prev = t_csr / t_el
    if crossover:
        emit("fig15_crossover_selectivity", crossover * 1e6, f"~{crossover}")

    # -- adaptive dispatch through the topology plane ------------------------
    # the full edge_scan path (frontier test + materialization) under each
    # forced strategy, then "auto": the dispatcher should pick the faster
    # side at both ends of the crossover.
    eng.plane.csr("Edge")  # build once outside the timed region
    tracked = 0
    for sel in sels:
        frontier = VSet.from_dense_ids("Node", n, frontiers[sel])
        _, t_el = timed(eng.edge_scan, frontier, "Edge", strategy="edgelist",
                        repeats=repeats)
        _, t_csr = timed(eng.edge_scan, frontier, "Edge", strategy="csr",
                         repeats=repeats)
        _, t_auto = timed(eng.edge_scan, frontier, "Edge", strategy="auto",
                          repeats=repeats)
        picked = eng.plane.last_strategy["Edge"]
        faster = "csr" if t_csr < t_el else "edgelist"
        if picked == faster:
            tracked += 1
        emit(f"fig15_scan_sel{sel}_auto_us", t_auto * 1e6,
             f"picked={picked};faster={faster};"
             f"el={t_el*1e6:.0f}us;csr={t_csr*1e6:.0f}us")
    emit("fig15_auto_tracks_faster", tracked,
         f"of {len(sels)} selectivities (threshold="
         f"{eng.plane.threshold()})")
    eng.close()
