"""Streaming ingestion driver: tail a JSONL change log into live epochs.

The file-drop CDC shape (DESIGN.md §12): an upstream producer appends
serialized :class:`ChangeEvent` lines to a JSONL file, a
:class:`FileTailSource` tails it, and the session's ingestion pipeline
micro-batches the stream into CAS-fenced lake commits and publishes each
batch through an epoch advance — so installed GSQL queries see fresh rows
within the flush cadence, while the same session keeps serving.

    PYTHONPATH=src python examples/stream_ingest.py
"""

import json
import os
import tempfile
import threading
import time

import repro
from repro.data.ldbc import generate_ldbc, ldbc_graph_schema
from repro.ingest import ChangeEvent, FileTailSource, IngestConfig, append_jsonl
from repro.lakehouse.objectstore import ObjectStore, StoreConfig


def main() -> None:
    root = tempfile.mkdtemp(prefix="graphlake_ingest_")
    store = ObjectStore(StoreConfig(root=root))
    ds = generate_ldbc(store, scale_factor=0.01)
    log_path = os.path.join(root, "changes.jsonl")

    with repro.connect(store, ldbc_graph_schema()) as session:
        engine = session.engine
        print(f"engine up in {engine.startup_seconds:.3f}s "
              f"(epoch {engine.current_epoch().epoch_id}, "
              f"{engine.current_epoch().n_real_vertices('Comment')} comments)")
        session.install(
            "creators",
            "SELECT p FROM Comment:c -(HasCreator:e)- Person:p "
            "ACCUM p.@cnt += 1")

        # 25ms micro-batch cadence; each committed batch is published by
        # the next epoch advance
        pipe = session.ingest(IngestConfig(flush_interval_s=0.025))
        pipe.attach_source(FileTailSource(log_path))

        # producer: append CDC lines — new comments with their HasCreator
        # edge, one straggler update, one delete — while the pipeline tails
        def produce() -> None:
            base = ds.n_comments
            for i in range(40):
                cid = (base + 1 + i) * 10 + 3
                append_jsonl(log_path, [
                    ChangeEvent(table="Comment", op="upsert",
                                row={"id": cid, "creationDate": 20130101,
                                     "length": i + 1,
                                     "browserUsed": "Chrome"}),
                    ChangeEvent(table="Comment_HasCreator_Person",
                                op="upsert",
                                row={"src": cid, "dst": 11,
                                     "creationDate": 20130101}),
                ])
                time.sleep(0.005)
            append_jsonl(log_path, [
                # straggler update of the first streamed comment...
                ChangeEvent(table="Comment", op="upsert",
                            row={"id": (base + 1) * 10 + 3,
                                 "creationDate": 20130101, "length": 777,
                                 "browserUsed": "Firefox"}),
                # ...and a delete of a seed comment (raw id 13)
                ChangeEvent(table="Comment", op="delete", key=(13,)),
            ])

        producer = threading.Thread(target=produce)
        producer.start()
        producer.join()
        assert pipe.drain(timeout=30.0), "pipeline failed to drain"

        epoch = engine.current_epoch()
        print(f"drained at epoch {epoch.epoch_id}: "
              f"{epoch.n_real_vertices('Comment')} comments "
              f"(+40 streamed, -1 deleted)")
        result = session.query("creators")
        print(f"creators query over fresh epoch: vset={result.vset.size()}")

        stats = pipe.stats()
        f = stats["freshness"]
        print("committer:", json.dumps(stats["committer"]))
        print(f"freshness over {f['samples']} batches: "
              f"commit->queryable p50={f['commit_to_queryable_p50_s']*1e3:.1f}ms "
              f"p99={f['commit_to_queryable_p99_s']*1e3:.1f}ms | "
              f"ingest->queryable p99="
              f"{f['ingest_to_queryable_p99_s']*1e3:.1f}ms")


if __name__ == "__main__":
    main()
