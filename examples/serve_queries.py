"""End-to-end serving driver: batched BI queries against GraphLake.

This is the paper-kind end-to-end example (a query/analytics engine serving
batched requests), mirroring §7.5's wrk2 evaluation in-process.

    PYTHONPATH=src python examples/serve_queries.py
"""

import json
import random
import tempfile
import time

from repro.core.bi_queries import BI_QUERIES
from repro.core.engine import GraphLakeEngine
from repro.data.ldbc import generate_ldbc, ldbc_graph_schema
from repro.lakehouse.objectstore import ObjectStore, StoreConfig
from repro.serving.server import QueryServer, ServerConfig, latency_stats


def main() -> None:
    root = tempfile.mkdtemp(prefix="graphlake_serve_")
    store = ObjectStore(StoreConfig(root=root))
    generate_ldbc(store, scale_factor=0.02)

    with GraphLakeEngine(store, ldbc_graph_schema()) as engine:
        engine.startup()
        print(f"engine up in {engine.startup_seconds:.3f}s "
              f"({engine.startup_mode})")

        server = QueryServer(engine, BI_QUERIES, ServerConfig(n_workers=2))
        rng = random.Random(0)
        requests = []
        for _ in range(60):
            name = rng.choice(list(BI_QUERIES))
            params = {}
            if name == "bi1":
                params = {"date": rng.choice([20090101, 20120101, 20150101]),
                          "tag_name": rng.choice(["Music", "Sports", "Movies"])}
            elif name == "bi4":
                params = {"city": f"city_{rng.randrange(50)}"}
            elif name == "bi3":
                params = {"min_len": rng.choice([200, 500, 1000])}
            requests.append((name, params))

        t0 = time.perf_counter()
        results = server.run_batch(requests)
        wall = time.perf_counter() - t0
        server.close()

        ok = [r for r in results if r.ok]
        print(f"{len(ok)}/{len(results)} ok | "
              f"throughput {len(ok)/wall:.1f} q/s")
        print("latency:", json.dumps(
            {k: round(v, 4) for k, v in latency_stats(results).items()}))
        print("cache:", engine.cache.stats)
        sample = next(r for r in results if r.ok)
        print("sample result:", sample.value)


if __name__ == "__main__":
    main()
