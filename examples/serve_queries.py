"""End-to-end serving driver: installed GSQL queries served in batches.

This is the paper-kind end-to-end example (a query/analytics engine serving
batched requests), mirroring §7.5's wrk2 evaluation in-process: a GSQL
session installs the BI suite (parse + schema validation up front), the
server executes installed names with bound parameters through
``session.query()``, admission control sheds load when the bounded queue
fills, and ``ServerConfig.timeout_s`` bounds each query's execution.

    PYTHONPATH=src python examples/serve_queries.py
"""

import json
import random
import tempfile
import time

import repro
from repro.core.bi_queries import BI_QUERIES, install_bi_queries
from repro.data.ldbc import generate_ldbc, ldbc_graph_schema
from repro.lakehouse.objectstore import ObjectStore, StoreConfig
from repro.serving.server import (
    QueryServer,
    ServerConfig,
    ServerOverloadedError,
    latency_stats,
)


def main() -> None:
    root = tempfile.mkdtemp(prefix="graphlake_serve_")
    store = ObjectStore(StoreConfig(root=root))
    generate_ldbc(store, scale_factor=0.02)

    with repro.connect(store, ldbc_graph_schema()) as session:
        engine = session.engine
        print(f"engine up in {engine.startup_seconds:.3f}s "
              f"({engine.startup_mode})")
        install_bi_queries(session)
        print(f"installed: {sorted(session.installed_queries())}")

        server = QueryServer(session,
                             config=ServerConfig(n_workers=2, timeout_s=30.0))
        rng = random.Random(0)
        requests = []
        for _ in range(60):
            name = rng.choice(sorted(session.installed_queries()))
            params = {"bi1": lambda: {"tag": rng.choice(["Music", "Sports", "Movies"]),
                                      "date": rng.choice([20090101, 20120101, 20150101])},
                      "bi2": lambda: {"lo": 20100101, "hi": 20151231},
                      "bi3": lambda: {"min_len": rng.choice([200, 500, 1000])},
                      "bi4": lambda: {"city": f"city_{rng.randrange(50)}"},
                      "bi5": lambda: {"min_degree": 10, "date": 20140101},
                      }[name]()
            requests.append((name, params))

        t0 = time.perf_counter()
        rids = []
        shed = 0
        for name, params in requests:
            try:
                rids.append(server.submit(name, **params))
            except ServerOverloadedError:   # admission control at the edge
                shed += 1
        results = [server.result(r) for r in rids]
        wall = time.perf_counter() - t0
        server.close()

        ok = [r for r in results if r.ok]
        print(f"{len(ok)}/{len(results)} ok ({shed} shed) | "
              f"throughput {len(ok)/wall:.1f} q/s")
        print("latency:", json.dumps(
            {k: round(v, 4) for k, v in latency_stats(results).items()}))
        print("cache:", engine.cache.stats)
        sample = next(r for r in results if r.ok)
        print(f"sample result: vset={sample.value.vset.size()} "
              f"epoch={sample.value.epoch_id} "
              f"staleness={sample.value.staleness_s:.2f}s")
        # summary-shaped results still come from the same session/GSQL path
        print("bi1 summary:", BI_QUERIES["bi1"](session, tag_name="Music"))


if __name__ == "__main__":
    main()
