"""Quickstart: build a Lakehouse, connect a GSQL session, query + PageRank.

    PYTHONPATH=src python examples/quickstart.py
"""

import tempfile

import repro
from repro.core.algorithms import pagerank
from repro.data.ldbc import generate_ldbc, ldbc_graph_schema
from repro.lakehouse.objectstore import ObjectStore, StoreConfig

BI1 = """
SELECT p
FROM Tag:t -(HasTag:e1)- Comment:c -(HasCreator:e2)- Person:p
WHERE t.name == $tag AND e2.creationDate > $date AND p.gender == 'Female'
ACCUM p.@cnt += 1
"""


def main() -> None:
    # 1. a Lakehouse: LDBC-style social network written as Iceberg-like tables
    root = tempfile.mkdtemp(prefix="graphlake_quickstart_")
    store = ObjectStore(StoreConfig(root=root))
    ds = generate_ldbc(store, scale_factor=0.01)
    print(f"lake at {root}: {ds.n_persons} persons, {ds.n_comments} comments, "
          f"{ds.n_edges} edges across "
          f"{len(store.list('tables/'))} objects")

    # 2. connect: engine startup (topology-only load, paper §4) + session
    with repro.connect(store, ldbc_graph_schema()) as session:
        engine = session.engine
        print(f"startup ({engine.startup_mode}): {engine.startup_seconds:.3f}s")
        print(f"topology: {engine.topology.n_edges()} edges in "
              f"{engine.topology.topology_bytes()/1e6:.1f} MB "
              f"(properties stay in the lake)")

        # 3. the paper's running example (§6) as GSQL text with parameters
        result = session.query(BI1, tag="Music", date=20100101)
        print(f"women with Music comments after 2010: {result.vset.size()} "
              f"({result.accumulators['cnt'].sum():.0f} comments, "
              f"{result.n_edges_scanned} edges scanned, "
              f"epoch {result.epoch_id})")

        # 3b. what the compiler planned: staged columns, zone-map bounds,
        # topology dispatch — before running anything
        print("-- explain --")
        print(session.explain(BI1, tag="Music", date=20100101))

        # 3c. install once, run many (what the serving layer does)
        session.install("bi1", BI1)
        again = session.query("bi1", tag="Sports", date=20120101)
        print(f"installed bi1(Sports, 2012): {again.vset.size()} persons")

        # 4. a graph algorithm over the same topology (Table 2)
        ranks = pagerank(engine, "Knows")
        top = ranks.argsort()[-3:][::-1]
        print(f"top-3 PageRank persons (dense ids): {top.tolist()}, "
              f"mass={ranks.sum():.4f}")

    # 5. second connection: materialized topology makes restarts fast
    with repro.connect(store, ldbc_graph_schema()) as session2:
        eng2 = session2.engine
        print(f"second connection: {eng2.startup_seconds:.3f}s "
              f"({eng2.startup_mode})")


if __name__ == "__main__":
    main()
