"""Quickstart: build a Lakehouse, start GraphLake, run a query + PageRank.

    PYTHONPATH=src python examples/quickstart.py
"""

import tempfile

from repro.core.algorithms import pagerank
from repro.core.engine import GraphLakeEngine
from repro.core.query import Query, accum_sum, eq, gt
from repro.data.ldbc import generate_ldbc, ldbc_graph_schema
from repro.lakehouse.objectstore import ObjectStore, StoreConfig


def main() -> None:
    # 1. a Lakehouse: LDBC-style social network written as Iceberg-like tables
    root = tempfile.mkdtemp(prefix="graphlake_quickstart_")
    store = ObjectStore(StoreConfig(root=root))
    ds = generate_ldbc(store, scale_factor=0.01)
    print(f"lake at {root}: {ds.n_persons} persons, {ds.n_comments} comments, "
          f"{ds.n_edges} edges across "
          f"{len(store.list('tables/'))} objects")

    # 2. start the engine: topology-only load (the paper's §4)
    with GraphLakeEngine(store, ldbc_graph_schema()) as engine:
        timings = engine.startup()
        print(f"startup ({engine.startup_mode}): "
              f"{engine.startup_seconds:.3f}s  phases={ {k: round(v,3) for k,v in timings.items()} }")
        print(f"topology: {engine.topology.n_edges()} edges in "
              f"{engine.topology.topology_bytes()/1e6:.1f} MB "
              f"(properties stay in the lake)")

        # 3. the paper's running example query (§6)
        result = (
            Query(engine)
            .vertices("Tag", where=eq("name", "Music"))
            .hop("HasTag", direction="in")
            .hop("HasCreator", direction="out",
                 edge_where=gt("creationDate", 20100101),
                 target_where=eq("gender", "Female"),
                 accum=accum_sum("cnt", 1.0))
            .run()
        )
        print(f"women with Music comments after 2010: {result.vset.size()} "
              f"({result.accumulators['cnt'].sum():.0f} comments, "
              f"{result.n_edges_scanned} edges scanned)")

        # 4. a graph algorithm over the same topology (Table 2)
        ranks = pagerank(engine, "Knows")
        top = ranks.argsort()[-3:][::-1]
        print(f"top-3 PageRank persons (dense ids): {top.tolist()}, "
              f"mass={ranks.sum():.4f}")

        # 5. second connection: materialized topology makes restarts fast
    with GraphLakeEngine(store, ldbc_graph_schema()) as engine2:
        engine2.startup()
        print(f"second connection: {engine2.startup_seconds:.3f}s "
              f"({engine2.startup_mode})")


if __name__ == "__main__":
    main()
