"""Distributed graph analytics: file-based sharding + two-pass EdgeScan.

Runs the same aggregation on 1-node and 3-node partitioned engines (threads
stand in for compute nodes) and verifies they agree, printing the network
accounting the paper's §6.2 design minimizes (batched remote fetches with
filter pushdown, accumulator push-back).

    PYTHONPATH=src python examples/distributed_analytics.py
"""

import tempfile

import numpy as np

from repro.core.distributed import DistributedGraphLake
from repro.core.engine import GraphLakeEngine
from repro.data.ldbc import generate_ldbc, ldbc_graph_schema
from repro.lakehouse.objectstore import ObjectStore, StoreConfig


def main() -> None:
    root = tempfile.mkdtemp(prefix="graphlake_dist_")
    store = ObjectStore(StoreConfig(root=root))
    ds = generate_ldbc(store, scale_factor=0.01, n_files=6)
    print(f"lake: {ds.n_comments} comments, {ds.n_edges} edges in 6 files/table")

    # single-node reference
    with GraphLakeEngine(store, ldbc_graph_schema(),
                         materialize_topology=False) as ref:
        ref.startup()
        frontier = ref.all_vertices("Comment")
        frame = ref.edge_scan(
            frontier, "HasCreator", "out",
            edge_columns=["creationDate"], v_columns=["gender"],
            edge_filter=lambda fr: (fr["e.creationDate"] > 20150101)
            & np.asarray([g == "Female" for g in fr["v.gender"]]),
        )
        ref_counts = np.bincount(frame.v, minlength=ref.topology.n_vertices("Person"))
        print(f"single node: {len(frame)} qualifying edges")

    # 3-node partitioned engine: every node owns 1/3 of the edge files
    dist = DistributedGraphLake(store, ldbc_graph_schema(), n_partitions=3)
    try:
        dist.startup()
        print(f"distributed startup: {dist.startup_seconds:.3f}s; per-node edges:",
              [e.topology.n_edges("HasCreator") for e in dist.engines])
        frontier = dist.engines[0].all_vertices("Comment")
        nxt, accum = dist.edge_scan_accumulate(
            frontier, "HasCreator", "out",
            edge_columns=["creationDate"], v_columns=["gender"],
            edge_filter=lambda fr: fr["e.creationDate"] > 20150101,
            v_filter=lambda fr: np.asarray([g == "Female" for g in fr["v.gender"]]),
        )
        assert np.allclose(accum, ref_counts), "distributed != single-node!"
        print(f"two-pass EdgeScan matches single node exactly "
              f"({int(accum.sum())} edges to {nxt.size()} persons)")
        print(f"network: {dist.net.requests} batched remote requests, "
              f"{dist.net.vertex_rows_shipped} vertex rows shipped "
              f"(filter pushdown dropped the rest), "
              f"{dist.net.accum_updates_shipped} accumulator partials")
    finally:
        dist.close()


if __name__ == "__main__":
    main()
