"""Train GIN (reduced config) for a few hundred steps with the full
fault-tolerant stack: stateless pipeline, async checkpointing, resume.

    PYTHONPATH=src python examples/train_gnn.py
"""

import tempfile

import jax
import numpy as np

from repro.configs import get_arch
from repro.data.pipeline import StatelessPipeline
from repro.train.loop import TrainLoopConfig, run_training


def main() -> None:
    arch = get_arch("gin-tu")
    cell = [c for c in arch.shapes() if c.name == "molecule"][0]
    ckpt_dir = tempfile.mkdtemp(prefix="gin_ckpt_")

    def make_batch(seed, step, shard, n_shards):
        # fixed dataset of 8 graph batches, cycled (so the model can overfit
        # and the loss visibly decreases)
        batch = arch.example_batch(cell, seed=step % 8, reduced=True)
        batch.pop("n_graphs", None)
        return batch

    step_fn = arch.make_step(cell, reduced=True)
    init = lambda: arch.init_state(jax.random.PRNGKey(0), cell, reduced=True)

    pipeline = StatelessPipeline(make_batch)
    result = run_training(init, step_fn, pipeline, TrainLoopConfig(
        total_steps=200, checkpoint_every=100, checkpoint_dir=ckpt_dir))
    pipeline.close()
    print(f"trained {result.steps_run} steps; "
          f"loss {np.mean(result.losses[:10]):.4f} -> "
          f"{np.mean(result.losses[-10:]):.4f}")

    # resume from the checkpoint and train 100 more steps
    pipeline2 = StatelessPipeline(make_batch)
    result2 = run_training(init, step_fn, pipeline2, TrainLoopConfig(
        total_steps=300, checkpoint_every=100, checkpoint_dir=ckpt_dir))
    pipeline2.close()
    print(f"resumed from step {result2.resumed_from}, ran "
          f"{result2.steps_run} more; final loss "
          f"{np.mean(result2.losses[-10:]):.4f}")


if __name__ == "__main__":
    main()
