"""Cache-manager concurrency: parallel hammering, exact byte accounting,
bounded eviction work, single-flight admission (DESIGN.md §5)."""

import threading

import numpy as np
import pytest

from repro.core.cache.manager import CacheConfig, CacheManager
from repro.core.cache.units import ChunkRef
from repro.lakehouse.columnfile import write_column_file
from repro.lakehouse.io_pool import IOPool
from repro.lakehouse.objectstore import ObjectStore, StoreConfig


@pytest.fixture
def store(tmp_path):
    return ObjectStore(StoreConfig(root=str(tmp_path / "lake")))


def _file(store, key, n=512, n_cols=8, row_group_rows=128):
    cols = {f"c{i}": (np.arange(n, dtype=np.int64) * (i + 1)) % 1013
            for i in range(n_cols)}
    return write_column_file(store, key, cols, row_group_rows=row_group_rows)


def _all_refs(key, n_cols=8, n_groups=4):
    return [ChunkRef(key, f"c{i}", g) for i in range(n_cols) for g in range(n_groups)]


# ---------------------------------------------------------------------------
# the stress test: >=8 threads, tight budget, exact accounting afterwards
# ---------------------------------------------------------------------------

def test_concurrent_get_unit_storm_exact_accounting(store):
    meta = _file(store, "t/f0.col")
    refs = _all_refs("t/f0.col")
    # tight budget: a handful of units fit, so the storm continuously evicts
    budget = 6 * (128 * 8 * 2 + 600)
    mgr = CacheManager(store, CacheConfig(memory_budget_bytes=budget))

    # ground truth from a single-threaded pass over a separate manager
    solo = CacheManager(store)
    expected = {}
    rows = np.arange(128, dtype=np.int64)
    for r in refs:
        u = solo.get_unit(r, meta, "vertex")
        expected[r.cache_key()] = np.array(u.read(rows))

    n_threads = 10
    iters = 30
    errors = []
    barrier = threading.Barrier(n_threads)

    def worker(seed):
        rng = np.random.default_rng(seed)
        barrier.wait()
        try:
            for it in range(iters):
                if it % 7 == 3:
                    # exercise the batch entry point too
                    batch = [refs[j] for j in rng.integers(0, len(refs), size=4)]
                    units = mgr.get_units_batch([(r, meta, "vertex") for r in batch])
                    for r in batch:
                        u = units[r.cache_key()]
                        vals, _ = mgr.read_unit(u, rows)
                        np.testing.assert_array_equal(vals, expected[r.cache_key()])
                else:
                    r = refs[int(rng.integers(0, len(refs)))]
                    kind = "vertex" if rng.integers(0, 2) else "edge"
                    u = mgr.get_unit(r, meta, kind)
                    sub = np.sort(rng.integers(0, 128, size=32)).astype(np.int64)
                    vals, _ = mgr.read_unit(u, sub)
                    np.testing.assert_array_equal(
                        vals, expected[r.cache_key()][sub])
        except Exception as e:  # pragma: no cover - failure path
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(s,)) for s in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)  # no deadlock: every thread finishes
        assert not t.is_alive(), "worker deadlocked"
    assert not errors, errors

    # exact byte accounting after the storm: the incremental counter matches
    # a from-scratch re-sum of every live unit
    assert mgr.mem_bytes() == mgr.mem_bytes_recomputed()
    assert mgr.mem_bytes() >= 0
    # nothing left mid-admission
    assert not mgr._loading


def test_concurrent_misses_single_flight(store):
    """N threads racing over the same cold chunk fetch it from the lake once."""
    meta = _file(store, "t/f0.col")
    mgr = CacheManager(store)
    ref = ChunkRef("t/f0.col", "c0", 0)
    barrier = threading.Barrier(8)
    units = []

    def worker():
        barrier.wait()
        units.append(mgr.get_unit(ref, meta, "vertex"))

    threads = [threading.Thread(target=worker) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30)
        assert not t.is_alive()
    assert mgr.stats["lake_fetches"] == 1
    assert mgr.stats["misses"] == 1
    assert len({id(u) for u in units}) == 1  # everyone got the same unit


class _FlakyStore:
    """Delegating store whose first ``fail_times`` gets raise a *fatal*
    typed error (fatal so the retry layer can't heal it before it reaches
    the single-flight machinery under test)."""

    def __init__(self, inner, fail_times=1):
        self._inner = inner
        self._lock = threading.Lock()
        self.remaining = fail_times

    def get(self, key, *a, **k):
        with self._lock:
            if self.remaining > 0:
                self.remaining -= 1
                from repro.errors import MissingObjectError
                raise MissingObjectError("injected load failure", key=key)
        return self._inner.get(key, *a, **k)

    def __getattr__(self, name):
        return getattr(self._inner, name)


def test_single_flight_loader_failure_releases_waiters(store):
    """ISSUE 8 satellite: when the loading thread's fetch raises, racing
    waiters must receive the error or retry the load themselves — never
    hang on the per-key event, and never read a poisoned cached unit."""
    from repro.errors import MissingObjectError

    meta = _file(store, "t/f0.col")
    flaky = _FlakyStore(store, fail_times=1)
    mgr = CacheManager(flaky)
    ref = ChunkRef("t/f0.col", "c0", 0)
    barrier = threading.Barrier(8)
    outcomes = []
    out_lock = threading.Lock()
    rows = np.arange(128, dtype=np.int64)

    def worker():
        barrier.wait()
        try:
            u = mgr.get_unit(ref, meta, "vertex")
            vals, _ = mgr.read_unit(u, rows)
            with out_lock:
                outcomes.append(("ok", vals))
        except MissingObjectError as e:
            with out_lock:
                outcomes.append(("err", e))

    threads = [threading.Thread(target=worker) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30)
        assert not t.is_alive(), "waiter hung on a failed single-flight load"

    # exactly the one injected failure surfaced — to whichever thread held
    # the loading slot — and every other racer retried through to success
    errs = [o for o in outcomes if o[0] == "err"]
    oks = [o for o in outcomes if o[0] == "ok"]
    assert len(errs) == 1 and len(oks) == 7, outcomes
    expected = oks[0][1]
    for _, vals in oks[1:]:
        np.testing.assert_array_equal(vals, expected)
    # no stuck in-flight marker, no poisoned unit: a fresh caller succeeds
    assert not mgr._loading
    u = mgr.get_unit(ref, meta, "vertex")
    vals, _ = mgr.read_unit(u, rows)
    np.testing.assert_array_equal(vals, expected)
    # the failed attempt never counted as a lake fetch or admitted a unit
    assert mgr.stats["lake_fetches"] == 1


def test_get_units_batch_dedup_and_pool(store):
    meta = _file(store, "t/f0.col")
    mgr = CacheManager(store)
    reqs = [(ChunkRef("t/f0.col", "c0", 0), meta, "vertex"),
            (ChunkRef("t/f0.col", "c0", 0), meta, "vertex"),   # duplicate
            (ChunkRef("t/f0.col", "c1", 0), meta, "vertex")]
    with IOPool(n_threads=4) as pool:
        units = mgr.get_units_batch(reqs, pool=pool)
    assert len(units) == 2
    assert mgr.stats["lake_fetches"] == 2


# ---------------------------------------------------------------------------
# eviction-path complexity regression (ISSUE satellite): bounded work/admit
# ---------------------------------------------------------------------------

def test_eviction_bounded_work_per_admit(store):
    """Under a tight budget, admitting N units does O(N) total sweep steps —
    the sweep consults the incremental byte counter instead of re-summing
    every resident unit per iteration (the old O(n^2) eviction)."""
    n_cols = 64
    meta = _file(store, "t/big.col", n=256, n_cols=n_cols, row_group_rows=256)
    unit_bytes = 256 * 8 * 2 + 600
    mgr = CacheManager(store, CacheConfig(memory_budget_bytes=4 * unit_bytes))
    rows = np.arange(256, dtype=np.int64)
    for i in range(n_cols):
        u = mgr.get_unit(ChunkRef("t/big.col", f"c{i}", 0), meta, "edge")
        mgr.read_unit(u, rows)
    assert mgr.stats["evictions"] > 0
    # every admit evicts ~one unit over a ~4-entry ring; the per-admit sweep
    # work is bounded by the ring size (plus priority decrements), never by
    # the total number of units ever admitted
    assert mgr.stats["sweep_steps"] <= 16 * n_cols
    assert mgr.mem_bytes() == mgr.mem_bytes_recomputed()


def test_growth_deltas_keep_accounting_exact(store):
    """Decoded growth is charged incrementally (units report deltas through
    on_growth); no path re-sums, yet the counter never drifts."""
    meta = _file(store, "t/f0.col")
    mgr = CacheManager(store, CacheConfig(memory_budget_bytes=1 << 30))
    rows = np.arange(128, dtype=np.int64)
    for r in _all_refs("t/f0.col")[:16]:
        u = mgr.get_unit(r, meta, "vertex")
        u.read(rows[:13])          # partial decode: growth fires mid-read
        assert mgr.mem_bytes() == mgr.mem_bytes_recomputed()
        u.read(rows)               # extend the prefix: another delta
        assert mgr.mem_bytes() == mgr.mem_bytes_recomputed()
    mgr.drop_memory()
    assert mgr.mem_bytes() == 0 == mgr.mem_bytes_recomputed()
