"""Tests: streaming ingestion plane (DESIGN.md §12) — change-event model and
sources, bounded queue with typed backpressure, last-write-wins coalescing,
copy-on-write upserts, CDC-to-epoch freshness, oracle parity, and the
stalled-committer fault-injection path."""

import json
import threading
import time
import warnings

import numpy as np
import pytest

from repro.core.engine import GraphLakeEngine
from repro.data.ldbc import generate_ldbc, ldbc_graph_schema
from repro.errors import IngestBackpressureError, ReproError
from repro.ingest import (
    ChangeEvent,
    ChangeLog,
    FileTailSource,
    IngestConfig,
    IngestPipeline,
    IngestQueue,
    MicroBatchCommitter,
    append_jsonl,
    event_from_json,
    event_to_json,
)
from repro.lakehouse.columnfile import read_columns, read_footer
from repro.lakehouse.faults import FaultInjector, FaultRule
from repro.lakehouse.objectstore import ObjectStore, StoreConfig
from repro.lakehouse.table import ColumnSpec, LakeCatalog, TableSchema


@pytest.fixture
def store(tmp_path):
    return ObjectStore(StoreConfig(root=str(tmp_path / "lake")))


@pytest.fixture
def ldbc(store):
    return generate_ldbc(store, scale_factor=0.004, n_files=2, row_group_rows=256)


@pytest.fixture
def engine(store, ldbc):
    eng = GraphLakeEngine(store, ldbc.schema, materialize_topology=False)
    eng.startup()
    yield eng
    eng.close()


def _comment_row(cid, length=1, date=20130101, browser="Chrome"):
    return {"id": cid, "creationDate": date, "length": length,
            "browserUsed": browser}


def _table_rows(store, table, key_col="id"):
    """All rows of a lake table as {key: row_dict} (layout-independent)."""
    t = LakeCatalog(store).table(table)
    cols = [c.name for c in t.schema().columns]
    out = {}
    for fk in t.data_files():
        meta = read_footer(store, fk)
        data = read_columns(store, meta, cols)
        for i in range(meta.n_rows):
            row = {c: data[c][i] for c in cols}
            out[row[key_col]] = row
    return out


# ---------------------------------------------------------------------------
# change-event model + sources
# ---------------------------------------------------------------------------

def test_change_event_validation_and_json_roundtrip():
    with pytest.raises(ValueError):
        ChangeEvent(table="Comment", op="mutate")
    with pytest.raises(ValueError):
        ChangeEvent(table="Comment", op="upsert")          # row required
    with pytest.raises(ValueError):
        ChangeEvent(table="Comment", op="delete")          # key required
    e = ChangeEvent(table="Comment", op="delete", key=13)
    assert e.key == (13,)                                  # normalized
    assert e.event_time > 0                                # stamped

    up = ChangeEvent(table="Comment", op="upsert", key=(23,),
                     row=_comment_row(np.int64(23), length=np.int64(7)),
                     event_time=5.0)
    rt = event_from_json(json.loads(json.dumps(event_to_json(up))))
    assert rt.table == up.table and rt.op == "upsert"
    assert rt.row == {"id": 23, "creationDate": 20130101, "length": 7,
                      "browserUsed": "Chrome"}             # numpy -> plain
    assert rt.event_time == 5.0
    # LWW ordering: later event_time wins; seq breaks ties
    assert ChangeEvent(table="t", op="delete", key=1, event_time=2.0,
                       seq=0).ordering() \
        > ChangeEvent(table="t", op="delete", key=1, event_time=1.0,
                      seq=9).ordering()


def test_changelog_poll_rewind_history():
    log = ChangeLog()
    log.upsert("Comment", _comment_row(13), event_time=1.0)
    log.delete("Comment", 23, event_time=2.0)
    assert len(log) == 2
    first = log.poll(max_events=1)
    assert len(first) == 1 and first[0].op == "upsert"
    assert [e.op for e in log.poll()] == ["delete"]
    assert log.poll() == [] and len(log) == 0
    log.rewind()
    assert [e.op for e in log.poll()] == ["upsert", "delete"]
    assert len(log.history()) == 2


def test_file_tail_source_ignores_partial_trailing_line(tmp_path):
    path = str(tmp_path / "cdc.jsonl")
    src = FileTailSource(path)
    assert src.poll() == []                                # missing file
    append_jsonl(path, [ChangeEvent(table="Comment", op="delete", key=13,
                                    event_time=1.0)])
    with open(path, "a", encoding="utf-8") as f:           # torn tail
        f.write('{"table": "Comment", "op": "del')
    got = src.poll()
    assert len(got) == 1 and got[0].key == (13,)
    assert src.poll() == []                                # tail still torn
    with open(path, "a", encoding="utf-8") as f:           # writer finishes
        f.write('ete", "key": [23], "event_time": 2.0}\n')
    got = src.poll()
    assert len(got) == 1 and got[0].key == (23,)
    src.rewind()
    assert [e.key for e in src.poll()] == [(13,), (23,)]


# ---------------------------------------------------------------------------
# bounded queue: typed backpressure + watermark hysteresis
# ---------------------------------------------------------------------------

def test_queue_backpressure_typed_and_watermark_hysteresis():
    q = IngestQueue(max_events=8, high_watermark=0.75, low_watermark=0.25)
    ev = lambda i: ChangeEvent(table="t", op="delete", key=i, event_time=1.0)
    for i in range(6):
        q.offer(ev(i))
        assert q.saturated == (i >= 5)              # latches at 6/8
    for i in range(6, 8):
        q.offer(ev(i))
    with pytest.raises(IngestBackpressureError) as exc:
        q.offer(ev(99))
    # typed: catchable as the repro base AND as a stdlib RuntimeError
    assert isinstance(exc.value, ReproError)
    assert isinstance(exc.value, RuntimeError)
    assert q.counters["backpressure_trips"] == 1
    assert q.counters["watermark_trips"] == 1

    assert len(q.drain(4)) == 4                     # 4 left > low mark (2)
    assert q.saturated                              # hysteresis: still latched
    assert len(q.drain(2)) == 2                     # at the low mark now
    assert not q.saturated
    q.offer(ev(100))                                # accepts again, no re-trip
    assert q.counters["watermark_trips"] == 1


# ---------------------------------------------------------------------------
# coalescing: last-write-wins per (table, key)
# ---------------------------------------------------------------------------

def test_coalesce_last_write_wins(engine):
    c = MicroBatchCommitter(engine)
    mk = lambda length, et, seq: (ChangeEvent(
        table="Comment", op="upsert", key=(13,),
        row=_comment_row(13, length=length), event_time=et, seq=seq), 0.0)
    # in-order duplicate, then an *out-of-order* straggler: both coalesce,
    # the (event_time, seq)-greatest row survives
    c.ingest([mk(1, 10.0, 0), mk(2, 11.0, 1), mk(99, 9.0, 2)])
    assert c.pending_events() == 1
    assert c.counters["events_coalesced"] == 2
    records, errors = c.flush()
    assert not errors and len(records) == 1
    assert records[0].kind == "upsert" and records[0].n_events == 1
    assert _table_rows(engine.store, "Comment")[13]["length"] == 2
    # a delete with the greatest ordering wins the slot over the upserts
    c.ingest([mk(5, 20.0, 3),
              (ChangeEvent(table="Comment", op="delete", key=(13,),
                           event_time=21.0, seq=4), 0.0)])
    records, errors = c.flush()
    assert not errors
    assert c.counters["rows_deleted"] == 1
    assert 13 not in _table_rows(engine.store, "Comment")


# ---------------------------------------------------------------------------
# LakeTable.upsert_rows: copy-on-write single-snapshot semantics
# ---------------------------------------------------------------------------

@pytest.fixture
def kv_table(store):
    t = LakeCatalog(store).table("kv")
    t.create(TableSchema("kv", [
        ColumnSpec("id", "int64", role="primary_key"),
        ColumnSpec("val", "int64"),
        ColumnSpec("tag", "str"),
    ]))
    # two files: ids 0..9 and 10..19
    for lo in (0, 10):
        ids = np.arange(lo, lo + 10, dtype=np.int64)
        t.append_files([{"id": ids, "val": ids * 100,
                         "tag": np.array(["seed"] * 10, dtype=object)}])
    return t


def test_upsert_rows_insert_update_delete_one_snapshot(store, kv_table):
    t = kv_table
    snaps_before = len(t.snapshots())
    rows_before = t.current_snapshot().n_rows
    res = t.upsert_rows(
        {"id": np.array([5, 30], dtype=np.int64),       # 5 update, 30 insert
         "val": np.array([555, 3000], dtype=np.int64),
         "tag": np.array(["new", "new"], dtype=object)},
        key_columns=["id"], delete_keys=[12])
    assert res.snapshot is not None
    assert len(t.snapshots()) == snaps_before + 1       # ONE snapshot step
    assert (res.rows_inserted, res.rows_updated, res.rows_deleted) == (1, 1, 1)
    assert t.current_snapshot().n_rows == rows_before + 1 - 1

    rows = _table_rows(store, "kv")
    assert rows[5]["val"] == 555 and rows[5]["tag"] == "new"
    assert rows[30]["val"] == 3000
    assert 12 not in rows
    assert rows[7]["val"] == 700                        # survivors intact
    assert len(rows) == rows_before + 1 - 1             # no dup keys anywhere


def test_upsert_rows_rewrites_only_affected_files(store, kv_table):
    t = kv_table
    files_before = t.data_files()
    res = t.upsert_rows(
        {"id": np.array([3], dtype=np.int64),           # lives in file 1 only
         "val": np.array([42], dtype=np.int64),
         "tag": np.array(["x"], dtype=object)},
        key_columns=["id"])
    files_after = t.data_files()
    assert res.files_rewritten == 1
    assert files_before[1] in files_after               # untouched by identity
    assert files_before[0] not in files_after           # rewritten + delta
    assert _table_rows(store, "kv")[3]["val"] == 42


def test_upsert_rows_delete_only_and_noop(store, kv_table):
    t = kv_table
    res = t.upsert_rows(None, key_columns=["id"], delete_keys=[0, 1, 999])
    assert res.rows_deleted == 2 and res.rows_inserted == 0
    assert 0 not in _table_rows(store, "kv")
    # keys nobody has: no commit at all
    snaps = len(t.snapshots())
    res2 = t.upsert_rows(None, key_columns=["id"], delete_keys=[999])
    assert res2.snapshot is None and len(t.snapshots()) == snaps


def test_upsert_rows_rejects_in_batch_duplicates_and_bad_columns(kv_table):
    with pytest.raises(ValueError, match="duplicate keys"):
        kv_table.upsert_rows(
            {"id": np.array([1, 1], dtype=np.int64),
             "val": np.array([2, 3], dtype=np.int64),
             "tag": np.array(["a", "b"], dtype=object)},
            key_columns=["id"])
    with pytest.raises(ValueError, match="exactly the table columns"):
        kv_table.upsert_rows({"id": np.array([1], dtype=np.int64)},
                             key_columns=["id"])


# ---------------------------------------------------------------------------
# end-to-end: pipeline vs batch-committed oracle (zero lost, zero duplicated)
# ---------------------------------------------------------------------------

def test_pipeline_matches_batch_oracle(tmp_path, store, ldbc, engine):
    """Replay a duplicate-laden CDC stream through the pipeline, then replay
    the identical history into a fresh batch-committed lake; final table
    contents and a GSQL aggregate must agree key-for-key."""
    rng = np.random.default_rng(11)
    log = ChangeLog()
    base = ldbc.n_comments
    existing = [int(i) * 10 + 3 for i in range(1, base + 1)]
    t0 = 100.0
    for i in range(40):                     # new comments (some twice)
        cid = (base + 1 + i % 30) * 10 + 3
        log.upsert("Comment", _comment_row(cid, length=i + 1), event_time=t0 + i)
    for i in range(10):                     # updates of seed rows
        log.upsert("Comment", _comment_row(existing[i], length=9000 + i),
                   event_time=t0 + 50 + i)
    for i in range(5):                      # deletes (2 of them just-inserted)
        victim = existing[20 + i] if i < 3 else (base + 1 + i) * 10 + 3
        log.delete("Comment", victim, event_time=t0 + 70 + i)
    for i in range(15):                     # edge appends for new comments
        cid = (base + 1 + i) * 10 + 3
        log.upsert("Comment_HasCreator_Person",
                   {"src": cid, "dst": 11, "creationDate": 20130101},
                   event_time=t0 + 80 + i)

    pipe = IngestPipeline(engine, IngestConfig(flush_interval_s=0.01)).start()
    pipe.attach_source(log)
    deadline = time.monotonic() + 30.0
    while len(log) and time.monotonic() < deadline:
        time.sleep(0.01)
    assert pipe.drain(timeout=30.0), pipe.stats()
    s = pipe.stats()
    assert s["flush_errors"] == 0 and s["rejected"] == 0
    pipe.close()

    # oracle: same history, replayed through batch upsert_rows commits on a
    # fresh copy of the same seed lake
    ostore = ObjectStore(StoreConfig(root=str(tmp_path / "oracle")))
    generate_ldbc(ostore, scale_factor=0.004, n_files=2, row_group_rows=256)
    by_table = {}
    for e in log.history():
        key = (e.row["id"],) if (e.table == "Comment" and e.op == "upsert") \
            else ((e.row["src"], e.row["dst"])
                  if e.op == "upsert" else e.key)
        by_table.setdefault(e.table, {})[key] = e       # history is in order
    for table, slot in by_table.items():
        lt = LakeCatalog(ostore).table(table)
        cols = [c.name for c in lt.schema().columns]
        ups = [e for e in slot.values() if e.op == "upsert"]
        dels = [e.key for e in slot.values() if e.op == "delete"]
        keyc = ["id"] if lt.schema().primary_key else ["src", "dst"]
        lt.upsert_rows(
            {c: np.array([e.row[c] for e in ups],
                         dtype=(object if c == "browserUsed" else np.int64))
             for c in cols} if ups else None,
            key_columns=keyc, delete_keys=dels)

    for table in ("Comment", "Comment_HasCreator_Person"):
        if table == "Comment":
            got = _table_rows(store, table)
            want = _table_rows(ostore, table)
        else:
            got = {(r["src"], r["dst"]): r
                   for r in _table_rows(store, table, key_col="src").values()}
            want = {(r["src"], r["dst"]): r
                    for r in _table_rows(ostore, table, key_col="src").values()}
        assert got == want, f"{table} diverged from oracle"

    # and through the query engine: per-person counts over the ingested lake
    # equal the oracle engine's (raw-id keyed — dense ids differ by layout)
    def creator_counts(eng):
        sess = eng.session()
        res = sess.query(
            "SELECT p FROM Comment:c -(HasCreator:e)- Person:p "
            "WHERE c.length > 0 ACCUM p.@cnt += 1")
        acc = res.accumulators["cnt"]
        ep = eng.current_epoch()
        raw = ep.idm.raw_ids("Person")
        n = ep.n_real_vertices("Person")
        return {int(raw[i]): float(acc[i]) for i in range(n) if acc[i] > 0}

    oeng = GraphLakeEngine(ostore, ldbc_graph_schema(),
                           materialize_topology=False)
    oeng.startup()
    try:
        assert creator_counts(engine) == creator_counts(oeng)
    finally:
        oeng.close()


# ---------------------------------------------------------------------------
# freshness: commit -> queryable via the epoch driver
# ---------------------------------------------------------------------------

def test_epoch_driver_freshness_and_visibility(store, ldbc, engine):
    e0 = engine.current_epoch().epoch_id
    pipe = IngestPipeline(engine, IngestConfig(flush_interval_s=0.01)).start()
    try:
        base = ldbc.n_comments
        for i in range(25):
            pipe.upsert("Comment", _comment_row((base + 1 + i) * 10 + 3,
                                                length=i + 1))
        assert pipe.drain(timeout=30.0), pipe.stats()
        s = pipe.stats()
        assert engine.current_epoch().epoch_id > e0
        assert s["driver"]["advances"] >= 1
        assert s["driver"]["events_visible"] == 25
        f = s["freshness"]
        assert f["samples"] >= 1
        assert 0 < f["commit_to_queryable_p99_s"] < 30.0
        # end-to-end >= commit-to-queryable for the same batches
        assert (f["ingest_to_queryable_p99_s"]
                >= f["commit_to_queryable_p99_s"])
        # the new rows are genuinely queryable
        sess = engine.session()
        res = sess.query("SELECT p FROM Comment:c -(HasCreator:e)- Person:p "
                         "WHERE c.creationDate == 99 ACCUM p.@cnt += 1")
        assert res.epoch_id == engine.current_epoch().epoch_id
    finally:
        pipe.close()


def test_vertex_update_and_delete_visible_after_drain(store, ldbc, engine):
    n_before = engine.current_epoch().n_real_vertices("Comment")
    pipe = IngestPipeline(engine, IngestConfig(flush_interval_s=0.01)).start()
    try:
        pipe.upsert("Comment", _comment_row(13, length=777777))
        pipe.delete("Comment", 23)
        assert pipe.drain(timeout=30.0), pipe.stats()
        e1 = engine.current_epoch()
        assert e1.n_real_vertices("Comment") == n_before - 1
        sess = engine.session()
        res = sess.query(
            "SELECT p FROM Comment:c -(HasCreator:e)- Person:p "
            "WHERE c.length == 777777 ACCUM p.@cnt += 1")
        assert res.accumulators["cnt"].sum() == 1.0
    finally:
        pipe.close()


# ---------------------------------------------------------------------------
# stalled committer: typed backpressure under fault injection, then heal
# ---------------------------------------------------------------------------

def test_stalled_committer_sheds_typed_then_heals(store, ldbc, engine):
    # every table write fails -> flushes fail -> the queue fills -> offer()
    # sheds typed; healing the store lets the retained batch drain with
    # exactly-once commits
    store.faults = FaultInjector(
        [FaultRule(prefix="tables/", ops=("put", "put_if"),
                   transient_rate=1.0)], seed=3)
    pipe = IngestPipeline(engine, IngestConfig(
        flush_interval_s=0.01, max_queue=8)).start()
    try:
        base = ldbc.n_comments
        shed = 0
        deadline = time.monotonic() + 30.0
        i = 0
        while shed == 0 and time.monotonic() < deadline:
            try:
                pipe.upsert("Comment",
                            _comment_row((base + 1 + i % 40) * 10 + 3,
                                         length=i + 1))
                i += 1
            except IngestBackpressureError:
                shed += 1
            time.sleep(0.001)
        s = pipe.stats()
        assert shed == 1, s
        assert s["rejected"] == 1 and s["flush_errors"] >= 1, s
        assert s["backpressure_trips"] >= 1
        assert s["last_flush_error"] is not None

        store.faults = None                 # heal the lake
        assert pipe.drain(timeout=30.0), pipe.stats()
        rows = _table_rows(store, "Comment")
        ingested = {k: r for k, r in rows.items() if k > base * 10 + 3}
        # exactly-once: every admitted key present once, at its last value
        assert len(ingested) == min(i, 40)
        for k, r in ingested.items():
            assert rows[k]["length"] == r["length"]     # single row per key
    finally:
        pipe.close()


# ---------------------------------------------------------------------------
# wiring: perf flags, server health, session handle
# ---------------------------------------------------------------------------

def test_ingest_flag_hygiene(monkeypatch):
    from repro import perf_flags
    # defaults with no REPRO_OPTS
    monkeypatch.delenv("REPRO_OPTS", raising=False)
    assert perf_flags.enabled("ingest")
    assert IngestConfig().resolved_flush_interval() == pytest.approx(0.05)
    assert IngestConfig().resolved_max_queue() == 4096
    # flag tunables flow into the resolved config
    monkeypatch.setenv("REPRO_OPTS", "ingest=5,ingest_queue=16")
    with warnings.catch_warnings():
        warnings.simplefilter("error")      # recognized flags never warn
        assert IngestConfig().resolved_flush_interval() == pytest.approx(0.005)
        assert IngestConfig().resolved_max_queue() == 16
    # explicit config wins over the flag
    cfg = IngestConfig(flush_interval_s=0.2, max_queue=7)
    assert cfg.resolved_flush_interval() == 0.2
    assert cfg.resolved_max_queue() == 7
    # a typo still warns once
    monkeypatch.setenv("REPRO_OPTS", "ingset=5")
    perf_flags._checked.discard("ingset=5")
    with pytest.warns(UserWarning, match="ingset"):
        perf_flags.enabled("ingest")


def test_server_health_exposes_ingest_counters(store, ldbc, engine):
    from repro.serving.server import QueryServer, ServerConfig
    pipe = IngestPipeline(engine, IngestConfig(flush_interval_s=0.01)).start()
    server = QueryServer(engine, {}, ServerConfig(n_workers=1))
    try:
        pipe.upsert("Comment", _comment_row((ldbc.n_comments + 1) * 10 + 3))
        assert pipe.drain(timeout=30.0)
        h = server.health()
        assert h["ingest"]["submitted"] == 1
        assert h["ingest"]["committer"]["events_committed"] == 1
        assert h["ingest"]["freshness"]["samples"] >= 1
    finally:
        server.close()
        pipe.close()
    assert server.health().get("ingest") is None        # deregistered


def test_session_ingest_handle_lifecycle(engine):
    sess = engine.session()
    pipe = sess.ingest(IngestConfig(flush_interval_s=0.01))
    assert sess.ingest() is pipe                        # cached
    assert engine.ingest is pipe                        # registered
    with pytest.raises(ValueError, match="first call"):
        sess.ingest(IngestConfig())
    sess.close()
    assert engine.ingest is None                        # closed with session
