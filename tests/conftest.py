"""Test-suite bootstrap.

Provides a minimal fallback for ``hypothesis`` so the tier-1 command collects
every module even in containers without the package installed.  The fallback
actually *runs* each property test against a deterministic pseudo-random
sample of the declared strategy space (a poor man's ``@given``), so property
coverage degrades gracefully instead of disappearing.
"""

from __future__ import annotations

import functools
import inspect
import random
import sys
import types

try:  # pragma: no cover - exercised only when hypothesis is installed
    import hypothesis  # noqa: F401
except ImportError:
    _DEFAULT_MAX_EXAMPLES = 10

    class _Strategy:
        """A draw()-able value generator mirroring the hypothesis API subset
        used by this suite (integers / lists / sampled_from)."""

        def __init__(self, draw_fn):
            self._draw = draw_fn

        def draw(self, rng: random.Random):
            return self._draw(rng)

        def map(self, fn):
            return _Strategy(lambda rng: fn(self.draw(rng)))

        def filter(self, pred):
            def _draw(rng):
                for _ in range(1000):
                    v = self.draw(rng)
                    if pred(v):
                        return v
                raise ValueError("filter predicate too restrictive")

            return _Strategy(_draw)

    def _integers(min_value=0, max_value=1 << 30):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def _lists(elements, min_size=0, max_size=10, unique=False):
        def _draw(rng):
            n = rng.randint(min_size, max_size)
            out = [elements.draw(rng) for _ in range(n)]
            if unique:
                seen = list(dict.fromkeys(out))
                for _ in range(1000):
                    if len(seen) >= n:
                        break
                    v = elements.draw(rng)
                    if v not in seen:
                        seen.append(v)
                out = seen
            return out

        return _Strategy(_draw)

    def _sampled_from(options):
        options = list(options)
        return _Strategy(lambda rng: rng.choice(options))

    def _booleans():
        return _Strategy(lambda rng: bool(rng.randint(0, 1)))

    def _floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def _given(*arg_strategies, **kw_strategies):
        def deco(fn):
            # hypothesis fills the *rightmost* positional params; everything
            # to their left stays visible to pytest as fixtures.
            sig = inspect.signature(fn)
            params = list(sig.parameters.values())
            n_pos = len(arg_strategies)
            split = len(params) - n_pos
            drawn_names = [p.name for p in params[split:]]
            visible = [p for p in params[:split] if p.name not in kw_strategies]

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_hypothesis_max_examples", _DEFAULT_MAX_EXAMPLES)
                rng = random.Random(0xC0FFEE ^ (hash(fn.__qualname__) & 0xFFFF))
                for _ in range(n):
                    drawn = {
                        name: s.draw(rng)
                        for name, s in zip(drawn_names, arg_strategies)
                    }
                    drawn.update({k: s.draw(rng) for k, s in kw_strategies.items()})
                    fn(*args, **kwargs, **drawn)

            wrapper.__signature__ = sig.replace(parameters=visible)
            return wrapper

        return deco

    def _settings(max_examples=_DEFAULT_MAX_EXAMPLES, **_ignored):
        def deco(fn):
            fn._hypothesis_max_examples = max_examples
            return fn

        return deco

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.lists = _lists
    _st.sampled_from = _sampled_from
    _st.booleans = _booleans
    _st.floats = _floats

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.__is_fallback__ = True

    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
