"""Tests: the point-lookup serving tier (DESIGN.md §10) — traffic-light
route classification at install time, fast-path vs full-engine bit-parity
(vset / accumulators / n_edges_scanned / alias sets / result stamps),
parity and plan-cache invalidation across advance(), concurrent lookups
during an epoch swap, install idempotence, server routing around the batch
window, and the sampler drawing adjacency from the lookup service."""

import threading

import numpy as np
import pytest

from repro.core.engine import GraphLakeEngine
from repro.data.ldbc import generate_ldbc, ldbc_graph_schema
from repro.data.sampler import NeighborSampler
from repro.gsql.errors import GSQLCompileError
from repro.gsql.session import GraphSession
from repro.lakehouse.table import LakeCatalog
from repro.serving.server import QueryServer, ServerConfig


@pytest.fixture
def store(tmp_path):
    from repro.lakehouse.objectstore import ObjectStore, StoreConfig

    return ObjectStore(StoreConfig(root=str(tmp_path / "lake")))


@pytest.fixture
def ldbc(store):
    return generate_ldbc(store, scale_factor=0.004, n_files=2,
                         row_group_rows=256)


@pytest.fixture
def session(store, ldbc):
    eng = GraphLakeEngine(store, ldbc.schema, materialize_topology=False)
    eng.startup()
    s = GraphSession(eng)
    yield s
    eng.close()


def _person(session, i=0):
    return int(session.engine.topology.idm.raw_ids("Person")[i])


def _assert_result_parity(fast, full):
    """The fast path must be bit-identical to the full engine on the same
    epoch (pruning counters legitimately differ — green never reads)."""
    np.testing.assert_array_equal(fast.vset.mask, full.vset.mask)
    assert fast.vset.vertex_type == full.vset.vertex_type
    assert fast.n_edges_scanned == full.n_edges_scanned
    assert set(fast.accumulators) == set(full.accumulators)
    for k in fast.accumulators:
        np.testing.assert_array_equal(fast.accumulators[k],
                                      full.accumulators[k])
    assert set(fast.alias_sets) == set(full.alias_sets)
    for k in fast.alias_sets:
        np.testing.assert_array_equal(fast.alias_sets[k].mask,
                                      full.alias_sets[k].mask)


# ---------------------------------------------------------------------------
# route classification (the traffic-light table)
# ---------------------------------------------------------------------------

CLASSIFICATION_TABLE = [
    # (gsql, expected tier)
    ("SELECT p FROM Person:p WHERE p.id == $pid", "green"),
    ("SELECT c FROM Person:p <-(HasCreator:e)- Comment:c WHERE p.id == $pid",
     "green"),
    ("SELECT p FROM Person:p <-(HasCreator:e)- Comment:c WHERE p.id == $pid "
     "ACCUM p.@deg += 1", "green"),
    # non-key predicates / column-valued ACCUM need a column fetch: yellow
    ("SELECT p FROM Person:p WHERE p.id == $pid AND p.gender == \"Female\"",
     "yellow"),
    ("SELECT c FROM Person:p <-(HasCreator:e)- Comment:c WHERE p.id == $pid "
     "AND e.creationDate > $d", "yellow"),
    ("SELECT c FROM Person:p <-(HasCreator:e)- Comment:c WHERE p.id == $pid "
     "AND c.length >= $L", "yellow"),
    ("SELECT p FROM Person:p <-(HasCreator:e)- Comment:c WHERE p.id == $pid "
     "ACCUM p.@len += c.length", "yellow"),
    # everything else runs the full engine: red
    ("SELECT p FROM Person:p WHERE p.gender == \"Female\"", "red"),
    ("SELECT p FROM Tag:t -(HasTag:e1)- Comment:c -(HasCreator:e2)- Person:p "
     "WHERE t.name == $tag", "red"),
    ("SELECT p FROM Person:p WHERE p.id == $pid OR p.gender == \"Female\"",
     "red"),
]


def test_route_classification_table(session):
    for i, (text, tier) in enumerate(CLASSIFICATION_TABLE):
        iq = session.install(f"route_case_{i}", text)
        assert iq.route.tier == tier, (text, iq.route)
        assert (iq.lookup_plan is not None) == (tier != "red")
        if iq.lookup_plan is not None:
            assert iq.lookup_plan.tier == tier


# ---------------------------------------------------------------------------
# fast path vs full engine: bit-parity
# ---------------------------------------------------------------------------

def test_point_lookup_parity_and_stamps(session):
    pid = _person(session)
    session.install("pt", "SELECT p FROM Person:p WHERE p.id == $pid")
    fast = session.lookup("pt", pid=pid)
    full = session.query("pt", pid=pid)
    _assert_result_parity(fast, full)
    assert fast.vset.size() == 1
    # route/tier stamps: contents identical, provenance visible
    assert (fast.route, fast.tier) == ("lookup", "green")
    assert (full.route, full.tier) == ("full", "green")
    assert fast.epoch_id == full.epoch_id
    # green executes with no lake column access at all
    assert fast.pruning["chunks_read"] == 0
    assert fast.pruning["rows_decoded"] == 0


def test_single_hop_parity(session):
    pid = _person(session)
    session.install(
        "nb", "SELECT c FROM Person:p <-(HasCreator:e)- Comment:c "
              "WHERE p.id == $pid")
    fast = session.lookup("nb", pid=pid)
    full = session.query("nb", pid=pid)
    _assert_result_parity(fast, full)
    assert fast.n_edges_scanned > 0
    assert fast.tier == "green"


def test_yellow_hop_accum_parity(session):
    pid = _person(session)
    session.install(
        "cnt", "SELECT p FROM Person:p <-(HasCreator:e)- Comment:c "
               "WHERE p.id == $pid AND e.creationDate > $d "
               "ACCUM p.@n += 1")
    fast = session.lookup("cnt", pid=pid, d=20100101)
    full = session.query("cnt", pid=pid, d=20100101)
    _assert_result_parity(fast, full)
    assert fast.tier == "yellow"
    assert fast.accumulators["n"].sum() > 0
    # the accumulator key survives even when every edge is filtered out
    none = session.lookup("cnt", pid=pid, d=99999999)
    assert none.accumulators["n"].sum() == 0
    _assert_result_parity(none, session.query("cnt", pid=pid, d=99999999))


def test_column_valued_accum_and_target_where_parity(session):
    pid = _person(session)
    session.install(
        "w", "SELECT c FROM Person:p <-(HasCreator:e)- Comment:c "
             "WHERE p.id == $pid AND c.length > $L ACCUM c.@w += c.length")
    fast = session.lookup("w", pid=pid, L=5)
    full = session.query("w", pid=pid, L=5)
    _assert_result_parity(fast, full)


def test_unknown_vertex_id_matches_empty_full_result(session):
    session.install("pt", "SELECT p FROM Person:p WHERE p.id == $pid")
    session.install(
        "nb", "SELECT c FROM Person:p <-(HasCreator:e)- Comment:c "
              "WHERE p.id == $pid")
    for name in ("pt", "nb"):
        fast = session.lookup(name, pid=987654321)
        full = session.query(name, pid=987654321)
        _assert_result_parity(fast, full)
        assert fast.vset.size() == 0


def test_red_template_falls_through_to_full_engine(session):
    session.install(
        "red", "SELECT p FROM Tag:t -(HasTag:e1)- Comment:c "
               "-(HasCreator:e2)- Person:p WHERE t.name == $tag")
    res = session.lookup("red", tag="Music")
    assert (res.route, res.tier) == ("full", "red")
    _assert_result_parity(res, session.query("red", tag="Music"))


def test_lookup_rejects_unknown_params(session):
    session.install("pt", "SELECT p FROM Person:p WHERE p.id == $pid")
    with pytest.raises(GSQLCompileError, match="unknown parameter"):
        session.lookup("pt", pid=1, bogus=2)
    with pytest.raises(GSQLCompileError, match="unbound parameter"):
        session.lookup("pt")
    with pytest.raises(KeyError):
        session.lookup("never_installed", pid=1)


# ---------------------------------------------------------------------------
# install(): idempotence + plan-cache invalidation
# ---------------------------------------------------------------------------

def test_install_idempotent_on_identical_text(session):
    text = "SELECT p FROM Person:p WHERE p.id == $pid"
    a = session.install("pt", text)
    session.lookup("pt", pid=_person(session))      # arm the plan
    assert session.install("pt", text) is a          # same object, cache warm
    epoch = session.engine.current_epoch()
    assert "pt" in epoch.lookup_plans


def test_reinstall_with_changed_text_swaps_plan(session):
    pid = _person(session)
    session.install("q", "SELECT p FROM Person:p WHERE p.id == $pid")
    r1 = session.lookup("q", pid=pid)
    assert r1.vset.vertex_type == "Person"
    epoch = session.engine.current_epoch()
    assert epoch.lookup_plans["q"].plan.kind == "point"
    # different text under the same name: the armed entry must not leak
    session.install(
        "q", "SELECT c FROM Person:p <-(HasCreator:e)- Comment:c "
             "WHERE p.id == $pid")
    assert "q" not in epoch.lookup_plans
    r2 = session.lookup("q", pid=pid)
    assert r2.vset.vertex_type == "Comment"
    _assert_result_parity(r2, session.query("q", pid=pid))


# ---------------------------------------------------------------------------
# epochs: parity across advance(), concurrent lookups during the swap
# ---------------------------------------------------------------------------

def _append_comments_and_edges(store, eng, ldbc, n_new=25, date=20230601):
    new_cids = np.arange(ldbc.n_comments + 1, ldbc.n_comments + n_new + 1,
                         dtype=np.int64) * 10 + 3
    lake = LakeCatalog(store)
    lake.table("Comment").append_files([{
        "id": new_cids,
        "creationDate": np.full(n_new, date, dtype=np.int64),
        "length": np.arange(n_new, dtype=np.int64) + 1,
        "browserUsed": np.array(["Chrome"] * n_new, dtype=object),
    }])
    person_raw = eng.topology.idm.raw_ids("Person")
    lake.table("Comment_HasCreator_Person").append_files([{
        "src": new_cids,
        "dst": person_raw[np.arange(n_new) % len(person_raw)],
        "creationDate": np.full(n_new, date, dtype=np.int64),
    }])


def test_parity_across_advance(store, ldbc, session):
    pid = _person(session)
    session.install(
        "nb", "SELECT c FROM Person:p <-(HasCreator:e)- Comment:c "
              "WHERE p.id == $pid")
    before = session.lookup("nb", pid=pid)
    old_epoch = session.engine.current_epoch()
    assert "nb" in old_epoch.lookup_plans        # armed on the old epoch

    _append_comments_and_edges(store, session.engine, ldbc)
    report = session.engine.advance()
    assert report.changed

    # the new epoch starts with an empty plan cache (invalidation by
    # construction); the first lookup re-arms against the new CSR/IDM
    new_epoch = session.engine.current_epoch()
    assert new_epoch is not old_epoch
    assert "nb" not in new_epoch.lookup_plans
    after = session.lookup("nb", pid=pid)
    assert "nb" in new_epoch.lookup_plans
    _assert_result_parity(after, session.query("nb", pid=pid))
    assert after.epoch_id > before.epoch_id
    # person 0 authored some of the appended comments -> more neighbors
    assert after.n_edges_scanned > before.n_edges_scanned


def test_concurrent_lookups_during_epoch_swap(store, ldbc, session):
    pid = _person(session)
    session.install(
        "nb", "SELECT c FROM Person:p <-(HasCreator:e)- Comment:c "
              "WHERE p.id == $pid")
    n_before = session.lookup("nb", pid=pid).n_edges_scanned
    stop = threading.Event()
    failures: list = []
    counts: set = set()

    def hammer():
        while not stop.is_set():
            try:
                res = session.lookup("nb", pid=pid)
                counts.add((res.epoch_id, res.n_edges_scanned))
            except Exception as e:  # noqa: BLE001 - the test records any
                failures.append(repr(e))
                return

    threads = [threading.Thread(target=hammer) for _ in range(4)]
    for t in threads:
        t.start()
    _append_comments_and_edges(store, session.engine, ldbc)
    session.engine.advance()
    for _ in range(50):             # let lookups land on the new epoch
        session.lookup("nb", pid=pid)
    stop.set()
    for t in threads:
        t.join()
    assert not failures, failures
    # every observed (epoch, count) pair is one of the two consistent
    # snapshots — never a torn mix
    n_after = session.lookup("nb", pid=pid).n_edges_scanned
    by_epoch = {}
    for eid, n in counts:
        by_epoch.setdefault(eid, set()).add(n)
    for eid, ns in by_epoch.items():
        assert len(ns) == 1, f"torn counts {ns} within epoch {eid}"
    assert n_after > n_before


# ---------------------------------------------------------------------------
# primitive lookups: get_vertex / neighbors
# ---------------------------------------------------------------------------

def test_get_vertex_and_neighbors(session):
    pid = _person(session)
    v = session.get_vertex("Person", pid, columns=("gender", "birthday"))
    assert v is not None and {"dense_id", "gender", "birthday"} <= set(v)
    assert session.get_vertex("Person", 987654321) is None

    dense = session.neighbors("HasCreator", pid, direction="in", ids="dense")
    raw = session.neighbors("HasCreator", pid, direction="in", ids="raw")
    assert len(dense) == len(raw)
    # parity with the full engine's hop over the same seed
    session.install(
        "nb", "SELECT c FROM Person:p <-(HasCreator:e)- Comment:c "
              "WHERE p.id == $pid")
    full = session.query("nb", pid=pid)
    np.testing.assert_array_equal(np.unique(dense), full.vset.ids())
    assert len(session.neighbors("HasCreator", 987654321, direction="in")) == 0


# ---------------------------------------------------------------------------
# serving: lookups route around the batch window
# ---------------------------------------------------------------------------

def test_server_routes_lookups_around_batching(session):
    pid = _person(session)
    session.install("pt", "SELECT p FROM Person:p WHERE p.id == $pid")
    session.install(
        "red", "SELECT p FROM Tag:t -(HasTag:e1)- Comment:c "
               "-(HasCreator:e2)- Person:p WHERE t.name == $tag")
    server = QueryServer(session, config=ServerConfig(
        n_workers=2, batch_window_ms=50.0, refresh_interval_s=0.0))
    try:
        rids = [server.submit("pt", pid=pid) for _ in range(6)]
        results = [server.result(r) for r in rids]
        assert all(r.ok for r in results)
        for r in results:
            assert r.value.route == "lookup"
            assert r.value.tier == "green"
        # lookups never waited out the 50 ms batch window
        assert server.stats["lookup_requests"] == 6
        assert server.stats["route_green"] == 6
        assert server.stats["batches"] == 0
        # a red template still takes the normal scheduler path
        rid = server.submit("red", tag="Music")
        res = server.result(rid)
        assert res.ok and res.value.route == "full"
        assert server.stats["lookup_requests"] == 6   # unchanged
    finally:
        server.close()


# ---------------------------------------------------------------------------
# the GNN sampler draws adjacency from the lookup service
# ---------------------------------------------------------------------------

def test_sampler_from_lookup_matches_manual_build(session):
    eng = session.engine
    epoch = eng.current_epoch()
    csr = epoch.plane.csr("HasCreator")
    src = np.repeat(np.arange(len(csr.fwd_indptr) - 1),
                    np.diff(csr.fwd_indptr))
    manual = NeighborSampler(src, csr.fwd_dst,
                             n_nodes=len(csr.fwd_indptr) - 1)
    via_lookup = NeighborSampler.from_lookup(session, "HasCreator",
                                             direction="out")
    np.testing.assert_array_equal(manual.indptr, via_lookup.indptr)
    np.testing.assert_array_equal(manual.dst_sorted, via_lookup.dst_sorted)
    seeds = np.arange(min(8, via_lookup.n_nodes), dtype=np.int64)
    a = manual.sample(seeds, fanout=(4, 2), n_pad=256, e_pad=512, seed=7)
    b = via_lookup.sample(seeds, fanout=(4, 2), n_pad=256, e_pad=512, seed=7)
    np.testing.assert_array_equal(a.src, b.src)
    np.testing.assert_array_equal(a.dst, b.dst)
    np.testing.assert_array_equal(a.node_ids, b.node_ids)
