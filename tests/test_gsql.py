"""Tests: GSQL front end — lexer/parser golden + error positions, IR
rendering, parse-time schema validation, parameter binding, and the fuzzed
builder -> IR -> text -> IR round trip (DESIGN.md §8)."""

import numpy as np
import pytest

from repro.core.query import (
    Query,
    accum_max,
    accum_sum,
    eq,
    ge,
    gt,
    isin,
    le,
    lt,
    ne,
)
from repro.data.ldbc import ldbc_graph_schema
from repro.gsql import ir
from repro.gsql.compiler import Catalog, compile_query, validate_query
from repro.gsql.errors import GSQLCompileError, GSQLSyntaxError
from repro.gsql.parser import parse

BI1 = """
SELECT p
FROM Tag:t -(HasTag:e1)- Comment:c -(HasCreator:e2)- Person:p
WHERE t.name == $tag AND e2.creationDate > $date AND p.gender == 'Female'
ACCUM p.@cnt += 1
"""


def _catalog() -> Catalog:
    return Catalog(
        schema=ldbc_graph_schema(),
        vertex_columns={
            "Person": frozenset({"id", "firstName", "gender", "birthday",
                                 "locationCity"}),
            "Comment": frozenset({"id", "creationDate", "length", "browserUsed"}),
            "Tag": frozenset({"id", "name"}),
        },
        edge_columns={
            "Knows": frozenset({"src", "dst", "creationDate"}),
            "HasCreator": frozenset({"src", "dst", "creationDate"}),
            "HasTag": frozenset({"src", "dst"}),
        },
    )


# ---------------------------------------------------------------------------
# parser golden
# ---------------------------------------------------------------------------

def test_parse_bi1_golden():
    lq = parse(BI1)
    assert len(lq.statements) == 1
    st = lq.statements[0]
    assert st.select_alias == "p"
    assert [v.vtype for v in st.vertices] == ["Tag", "Comment", "Person"]
    assert [v.alias for v in st.vertices] == ["t", "c", "p"]
    assert [h.edge_type for h in st.hops] == ["HasTag", "HasCreator"]
    assert all(h.direction == "auto" for h in st.hops)
    assert len(st.where) == 3
    c0, c1, c2 = st.where
    assert c0 == ir.Cmp(ref=ir.ColRef("t", "name"), op="==",
                        value=ir.Param("tag"))
    assert c1.ref.alias == "e2" and c1.op == ">" and c1.value == ir.Param("date")
    assert c2.value == "Female"
    (acc,) = st.accums
    assert acc.target == ir.ColRef("p", "cnt", is_accum=True)
    assert acc.op == "sum" and acc.value == 1


def test_parse_directions_and_post_accum():
    lq = parse("""
        SELECT c FROM Comment:c -(HasCreator:e)-> Person:p
        WHERE e.creationDate >= 5 AND e.creationDate <= 9
        POST-ACCUM c -(HasTag)- Tag:t ACCUM t.@tag_cnt += 1
    """)
    st = lq.statements[0]
    assert st.hops[0].direction == "out"
    (pb,) = st.post
    assert pb.source_alias == "c"
    assert pb.hop.edge_type == "HasTag" and pb.hop.alias is None
    assert pb.target == ir.VertexPat("Tag", "t")
    assert pb.accums[0].target.column == "tag_cnt"

    st2 = parse("SELECT a FROM Person:a <-(HasCreator:e)- Comment:b").statements[0]
    assert st2.hops[0].direction == "in"


def test_parse_multi_statement_or_in_and_comments():
    lq = parse("""
        # degree pass
        SELECT q FROM Person:a -(Knows:k)-> Person:q ACCUM a.@deg += 1;
        SELECT s FROM Person:s
        WHERE (s.gender == 'Female' OR s.gender == 'Male')
          AND s.locationCity IN ('city_1', 'city_2') AND s.@deg >= $k
    """)
    assert len(lq.statements) == 2
    st = lq.statements[1]
    assert st.hops == () and len(st.where) == 3
    assert isinstance(st.where[0], ir.OrCond) and len(st.where[0].items) == 2
    assert isinstance(st.where[1], ir.InSet)
    assert st.where[1].values == ("city_1", "city_2")
    assert st.where[2].ref.is_accum
    assert lq.param_names() == {"k"}


def test_parse_accum_ops_and_values():
    st = parse("""
        SELECT p FROM Comment:c -(HasCreator:e)- Person:p
        ACCUM p.@tot += c.length, p.@mx MAX= e.creationDate
    """).statements[0]
    a0, a1 = st.accums
    assert a0.op == "sum" and a0.value == ir.ColRef("c", "length")
    assert a1.op == "max" and a1.value == ir.ColRef("e", "creationDate")


@pytest.mark.parametrize("text,line,col,fragment", [
    ("SELECT p\nFORM Tag:t", 2, 1, "expected FROM"),
    ("SELECT p FROM Tag:t -(HasTag:e- Comment:c", 1, 31, "expected ')'"),
    ("SELECT p FROM Tag:t WHERE t.name = 'x'", 1, 34, "comparison operator"),
    ("SELECT p FROM Tag:t WHERE t.name == 'x", 1, 37, "unterminated string"),
    ("SELECT p FROM Tag:t WHERE t.name == ^", 1, 37, "unexpected character"),
    ("SELECT p FROM Tag:t ACCUM t.name += 1", 1, 27, "must be an accumulator"),
    ("SELECT p FROM Tag:t WHERE t.a == 1 OR (t.b == 2 AND t.c == 3)", 1, 39,
     "OR only joins simple comparisons"),
])
def test_syntax_errors_carry_positions(text, line, col, fragment):
    with pytest.raises(GSQLSyntaxError) as exc:
        parse(text)
    assert exc.value.line == line, str(exc.value)
    assert exc.value.col == col, str(exc.value)
    assert fragment in str(exc.value)
    assert f"line {line}" in str(exc.value)


def test_statement_junk_after_end():
    with pytest.raises(GSQLSyntaxError, match="missing ';'"):
        parse("SELECT p FROM Tag:t SELECT q FROM Tag:u")


# ---------------------------------------------------------------------------
# render round trip (hand-written)
# ---------------------------------------------------------------------------

def test_render_parses_back_to_equal_ir():
    lq = parse(BI1)
    assert parse(lq.render()) == lq
    lq2 = parse("""
        SELECT c FROM Comment:c -(HasCreator:e)-> Person:p
        WHERE e.creationDate >= $lo AND e.creationDate <= $hi
        POST-ACCUM c -(HasTag)- Tag:t ACCUM t.@tag_cnt += 1
    """)
    assert parse(lq2.render()) == lq2


# ---------------------------------------------------------------------------
# compile-time schema validation
# ---------------------------------------------------------------------------

def _compile(text: str, **params):
    return compile_query(parse(text), _catalog(), params)


@pytest.mark.parametrize("text,fragment", [
    ("SELECT p FROM Post:p", "unknown vertex type 'Post'"),
    ("SELECT p FROM Tag:t -(Likes:e)- Person:p", "unknown edge type 'Likes'"),
    ("SELECT t FROM Tag:t WHERE t.nam == 'x'", "no column 'nam'"),
    ("SELECT p FROM Comment:c -(HasCreator:e)- Person:p WHERE e.weight > 1",
     "no column 'weight'"),
    ("SELECT p FROM Person:p -(Knows:k)- Person:q", "ambiguous"),
    ("SELECT p FROM Tag:t -(HasCreator:e)- Person:p", "cannot link"),
    ("SELECT p FROM Tag:t -(HasTag:e)-> Comment:p", "expects Comment on the left"),
    ("SELECT t FROM Tag:t -(HasTag:t)- Comment:c", "duplicate alias 't'"),
    ("SELECT x FROM Tag:t", "SELECT alias 'x'"),
    ("SELECT t FROM Tag:t WHERE z.name == 'x'", "unknown alias 'z'"),
    ("SELECT c FROM Tag:t -(HasTag:e)- Comment:c WHERE t.name == c.id",
     "exactly one alias"),
    ("SELECT c FROM Tag:t -(HasTag:e)- Comment:c ACCUM e.@n += 1",
     "not a vertex alias"),
    ("SELECT t FROM Tag:t ACCUM t.@n += 1", "at least one hop"),
    ("SELECT p FROM Comment:c -(HasCreator:e)- Person:p "
     "ACCUM p.@a += 1, p.@b += 1", "already has an ACCUM"),
    ("SELECT p FROM Comment:c -(HasCreator:e)- Person:p WHERE p.@deg > 1",
     "seed vertex"),
    ("SELECT p FROM Tag:t -(HasTag:e1)- Comment:c -(HasCreator:e2)- Person:p "
     "ACCUM p.@x += t.name", "accumulating hop's endpoints"),
])
def test_compile_errors(text, fragment):
    with pytest.raises(GSQLCompileError) as exc:
        _compile(text)
    assert fragment in str(exc.value), str(exc.value)


def test_compile_error_position_points_at_column():
    text = "SELECT t FROM Tag:t\nWHERE t.nam == 'x'"
    with pytest.raises(GSQLCompileError) as exc:
        _compile(text)
    assert exc.value.line == 2 and exc.value.col == 7


def test_compiled_blocks_shape():
    compiled = _compile(BI1, tag="Music", date=20100101)
    (st,) = compiled.statements
    assert st.seed.vertex_type == "Tag" and st.seed.where is not None
    assert [h.direction for h in st.hops] == ["in", "out"]
    assert st.hops[1].edge_where is not None
    assert st.hops[1].target_where is not None
    assert st.hops[1].accum.name == "cnt" and st.hops[1].accum.target == "v"
    assert st.select == 2 and st.vertex_aliases == ["t", "c", "p"]
    assert compiled.accum_targets == [("Person", "cnt")]


# ---------------------------------------------------------------------------
# parameter binding
# ---------------------------------------------------------------------------

def test_param_binding_edge_cases():
    # missing parameter -> error naming it, with position
    with pytest.raises(GSQLCompileError, match=r"unbound parameter \$date"):
        _compile(BI1, tag="Music")
    # extra parameter -> error
    with pytest.raises(GSQLCompileError, match=r"unknown parameter\(s\): \$extra"):
        _compile(BI1, tag="Music", date=1, extra=2)
    # params inside IN lists and accum values bind too
    compiled = _compile(
        "SELECT s FROM Person:s -(Knows:k)-> Person:q "
        "WHERE s.locationCity IN ($a, 'city_2') ACCUM s.@w += $weight",
        a="city_1", weight=2.5)
    (st,) = compiled.statements
    assert st.seed.where is not None
    assert st.hops[0].accum.value == 2.5
    # string vs numeric binding both flow into predicate bounds
    b = _compile("SELECT t FROM Tag:t WHERE t.id == $x", x=7) \
        .statements[0].seed.where.bounds()
    assert b["id"].values == frozenset({7})


def test_validate_without_params_and_accum_param_numeric():
    # install-time validation: unbound params fine, returns their names
    assert validate_query(parse(BI1), _catalog()) == {"tag", "date"}
    # accumulator predicates need numeric values
    with pytest.raises(GSQLCompileError, match="numeric"):
        _compile("SELECT s FROM Person:s WHERE s.@deg >= $k", k="many")


# ---------------------------------------------------------------------------
# fuzz: builder -> IR -> GSQL text -> IR round trip
# ---------------------------------------------------------------------------

class _FakeEngine:
    schema = ldbc_graph_schema()


_STEPS = {
    # vertex type -> [(edge_type, direction, next_type), ...]
    "Tag": [("HasTag", "in", "Comment")],
    "Comment": [("HasCreator", "out", "Person"), ("HasTag", "out", "Tag")],
    "Person": [("Knows", "out", "Person"), ("Knows", "in", "Person"),
               ("HasCreator", "in", "Comment")],
}
_VCOLS = {
    "Person": ["gender", "birthday", "locationCity"],
    "Comment": ["creationDate", "length", "browserUsed"],
    "Tag": ["name"],
}
_ECOLS = {"Knows": ["creationDate"], "HasCreator": ["creationDate"],
          "HasTag": []}


def _random_pred(rng, cols):
    if not cols:
        return None
    col = rng.choice(cols)
    kind = rng.choice(["eq", "ne", "gt", "ge", "lt", "le", "isin", "and", "or"])
    mk = {"eq": eq, "ne": ne, "gt": gt, "ge": ge, "lt": lt, "le": le}
    if kind in mk:
        value = int(rng.integers(0, 10**8)) if rng.random() < 0.7 \
            else f"s{int(rng.integers(0, 99))}"
        return mk[kind](col, value)
    if kind == "isin":
        vals = [int(v) for v in rng.integers(0, 1000, size=int(rng.integers(1, 4)))]
        return isin(col, vals)
    # OR sides must stay simple for renderability; AND composes freely
    if kind == "or":
        a, b = eq(col, int(rng.integers(0, 99))), gt(col, int(rng.integers(0, 99)))
        return a | b
    return _random_pred(rng, [col]) & _random_pred(rng, [col])


def test_fuzz_builder_ir_text_round_trip():
    rng = np.random.default_rng(1234)
    n_ok = 0
    for _ in range(60):
        start = rng.choice(list(_STEPS))
        q = Query(_FakeEngine())
        q.vertices(start, where=_random_pred(rng, _VCOLS[start])
                   if rng.random() < 0.6 else None)
        cur = start
        for _hop in range(int(rng.integers(1, 4))):
            etype, direction, nxt = _STEPS[cur][int(rng.integers(0, len(_STEPS[cur])))]
            accum = None
            if rng.random() < 0.5:
                name = f"a{int(rng.integers(0, 5))}"
                if rng.random() < 0.5 and _VCOLS[nxt]:
                    accum = accum_sum(name, f"v.{rng.choice(_VCOLS[nxt])}")
                elif rng.random() < 0.5:
                    accum = accum_max(name, int(rng.integers(0, 100)),
                                      target=rng.choice(["u", "v"]))
                else:
                    accum = accum_sum(name, float(rng.integers(1, 5)),
                                      target=rng.choice(["u", "v"]))
            q.hop(etype, direction=direction,
                  edge_where=_random_pred(rng, _ECOLS[etype])
                  if rng.random() < 0.5 else None,
                  target_where=_random_pred(rng, _VCOLS[nxt])
                  if rng.random() < 0.4 else None,
                  accum=accum)
            cur = nxt
        lq = q.to_ir()
        text = lq.render()
        assert parse(text) == lq, f"round trip failed for:\n{text}"
        n_ok += 1
    assert n_ok == 60


def test_to_ir_rejects_opaque_predicates():
    q = Query(_FakeEngine()).vertices(
        "Person", where=Predicate_udf())
    with pytest.raises(ValueError, match="opaque"):
        q.to_ir()


def Predicate_udf():
    from repro.core.query import Predicate
    return Predicate(lambda f, p: np.ones(0, dtype=bool), ("gender",))


def test_builder_source_where_renders_on_source_alias():
    q = (Query(_FakeEngine())
         .vertices("Comment")
         .hop("HasCreator", direction="out", source_where=gt("length", 500),
              accum=accum_sum("tot_len", "u.length")))
    lq = q.to_ir()
    text = lq.render()
    assert "s.length > 500" in text
    assert "v1.@tot_len += s.length" in text
    assert parse(text) == lq


def test_accum_name_shared_across_vertex_types_rejected():
    with pytest.raises(GSQLCompileError, match="rename one"):
        _compile("SELECT p FROM Comment:c -(HasCreator:e)- Person:p "
                 "ACCUM p.@cnt += 1 "
                 "POST-ACCUM c -(HasTag:e2)- Tag:t ACCUM t.@cnt += 1")
