"""Tests: checkpointing, resume/restart, preemption, stragglers, compression,
stateless pipeline, optimizer."""

import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.data.pipeline import StatelessPipeline, lm_batch_maker, recsys_batch_maker
from repro.distributed.compression import (
    ErrorFeedbackCompressor, compression_ratio, dequantize_int8, quantize_int8,
)
from repro.distributed.fault import HeartbeatRegistry, PreemptionGuard, StragglerDetector
from repro.train.checkpoint import (
    AsyncCheckpointer, latest_step, restore_checkpoint, save_checkpoint,
)
from repro.train.loop import TrainLoopConfig, TrainResult, run_training
from repro.train.optimizer import AdamW, OptimizerConfig, make_train_state


def _toy_state():
    return {
        "params": {"w": jnp.arange(6.0).reshape(2, 3), "b": jnp.zeros(3)},
        "opt": {"m": {"w": jnp.ones((2, 3)), "b": jnp.zeros(3)},
                "v": {"w": jnp.ones((2, 3)), "b": jnp.zeros(3)}},
        "step": jnp.asarray(7, jnp.int32),
    }


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    root = str(tmp_path / "ckpt")
    state = _toy_state()
    save_checkpoint(root, 7, state)
    assert latest_step(root) == 7
    back = restore_checkpoint(root, jax.tree.map(np.zeros_like, state))
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_integrity_detects_corruption(tmp_path):
    root = str(tmp_path / "ckpt")
    state = _toy_state()
    path = save_checkpoint(root, 1, state)
    victim = os.path.join(path, "leaf_00000.npy")
    data = bytearray(open(victim, "rb").read())
    data[-1] ^= 0xFF
    open(victim, "wb").write(bytes(data))
    with pytest.raises(IOError):
        restore_checkpoint(root, state)


def test_checkpoint_retention(tmp_path):
    root = str(tmp_path / "ckpt")
    state = _toy_state()
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(root, s, state, keep=2)
    dirs = sorted(d for d in os.listdir(root) if d.startswith("step_"))
    assert dirs == ["step_00000004", "step_00000005"]


def test_checkpoint_atomicity_tmp_never_latest(tmp_path):
    root = str(tmp_path / "ckpt")
    save_checkpoint(root, 3, _toy_state())
    # a stale tmp dir from a crashed save must not confuse restore
    os.makedirs(os.path.join(root, "step_00000009.tmp"))
    assert latest_step(root) == 3


def test_async_checkpointer(tmp_path):
    root = str(tmp_path / "ckpt")
    ck = AsyncCheckpointer(root, keep=2)
    for s in (10, 20):
        ck.save(s, _toy_state())
    ck.close()
    assert latest_step(root) == 20


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    root = str(tmp_path / "ckpt")
    save_checkpoint(root, 1, _toy_state())
    bad = _toy_state()
    bad["params"]["w"] = jnp.zeros((4, 4))
    with pytest.raises(ValueError):
        restore_checkpoint(root, bad)


# ---------------------------------------------------------------------------
# fault machinery
# ---------------------------------------------------------------------------

def test_heartbeat_detects_dead_worker():
    hb = HeartbeatRegistry(timeout_s=0.05)
    hb.tick("a")
    hb.tick("b")
    assert hb.healthy()
    time.sleep(0.08)
    hb.tick("a")
    assert hb.dead_workers() == ["b"]


def test_straggler_detector():
    det = StragglerDetector(threshold=2.0)
    for i in range(10):
        det.record(i, 0.1)
    assert det.record(10, 0.5) is True
    assert det.flagged_steps == [10]
    assert det.record(11, 0.12) is False


def test_preemption_guard_programmatic():
    g = PreemptionGuard(install=False)
    assert not g.should_stop()
    g.request()
    assert g.should_stop()


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_quantize_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(1000), jnp.float32)
    q, scale = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, scale) - x))
    assert err.max() <= float(scale) / 2 + 1e-6


def test_error_feedback_unbiased_over_steps():
    """With EF, the *accumulated* applied gradient converges to the true sum."""
    rng = np.random.default_rng(1)
    true = jnp.asarray(rng.standard_normal((64,)) * 1e-3, jnp.float32)
    comp = ErrorFeedbackCompressor()
    residual = None
    applied = jnp.zeros_like(true)
    for _ in range(200):
        g, residual = comp({"g": true}, residual if residual is None else residual)
        applied = applied + g["g"]
    expect = true * 200
    # relative error of accumulated gradient should be tiny thanks to EF
    rel = float(jnp.linalg.norm(applied - expect) / jnp.linalg.norm(expect))
    assert rel < 0.01, rel


def test_compression_ratio():
    grads = {"w": jnp.zeros((1000,), jnp.float32)}
    assert compression_ratio(grads) > 3.5


# ---------------------------------------------------------------------------
# stateless pipeline
# ---------------------------------------------------------------------------

def test_pipeline_determinism_and_resume():
    make = lm_batch_maker(vocab=97, batch=8, seq=16)
    p1 = StatelessPipeline(make, seed=3)
    p2 = StatelessPipeline(make, seed=3)
    try:
        b5a = p1.batch_at(5)
        b5b = p2.batch_at(5)
        np.testing.assert_array_equal(b5a["tokens"], b5b["tokens"])
        # shards partition the batch deterministically
        s0 = StatelessPipeline(make, seed=3, shard=0, n_shards=2).batch_at(5)
        assert s0["tokens"].shape[0] == 4
    finally:
        p1.close()
        p2.close()


def test_pipeline_iterate_prefetches_in_order():
    make = lm_batch_maker(vocab=17, batch=4, seq=8)
    p = StatelessPipeline(make, seed=0)
    try:
        steps = [s for s, _ in p.iterate(10, 5)]
        assert steps == [10, 11, 12, 13, 14]
    finally:
        p.close()


# ---------------------------------------------------------------------------
# end-to-end train loop: checkpoint/restart + preemption
# ---------------------------------------------------------------------------

def _tiny_setup():
    arch = get_arch("qwen2-1.5b")
    cell = arch.shapes()[0]
    step_fn = arch.make_step(cell, reduced=True)
    cfg = arch.config(reduced=True)
    make = lm_batch_maker(vocab=cfg.vocab, batch=4, seq=16)
    init = lambda: arch.init_state(jax.random.PRNGKey(0), cell, reduced=True)
    return init, step_fn, make


def test_train_loop_checkpoint_restart(tmp_path):
    init, step_fn, make = _tiny_setup()
    ckpt_dir = str(tmp_path / "ck")

    pipe = StatelessPipeline(make, seed=1)
    r1 = run_training(init, step_fn, pipe, TrainLoopConfig(
        total_steps=6, checkpoint_every=3, checkpoint_dir=ckpt_dir,
        async_checkpoint=False))
    pipe.close()
    assert r1.steps_run == 6 and latest_step(ckpt_dir) == 6

    # continue to 10: must resume from step 6, not restart
    pipe2 = StatelessPipeline(make, seed=1)
    r2 = run_training(init, step_fn, pipe2, TrainLoopConfig(
        total_steps=10, checkpoint_every=3, checkpoint_dir=ckpt_dir,
        async_checkpoint=False))
    pipe2.close()
    assert r2.resumed_from == 6
    assert r2.steps_run == 4
    assert int(np.asarray(r2.final_state["step"])) == 10

    # bitwise-identical to an uninterrupted 10-step run (exact resume)
    pipe3 = StatelessPipeline(make, seed=1)
    r3 = run_training(init, step_fn, pipe3, TrainLoopConfig(total_steps=10))
    pipe3.close()
    np.testing.assert_allclose(
        np.asarray(r2.final_state["params"]["ln_final"]),
        np.asarray(r3.final_state["params"]["ln_final"]), rtol=1e-6)


def test_train_loop_preemption_saves_and_exits(tmp_path):
    init, step_fn, make = _tiny_setup()
    ckpt_dir = str(tmp_path / "ck")
    guard = PreemptionGuard(install=False)
    guard.request()  # preempt immediately: loop must save at first boundary
    pipe = StatelessPipeline(make, seed=1)
    r = run_training(init, step_fn, pipe, TrainLoopConfig(
        total_steps=50, checkpoint_every=100, checkpoint_dir=ckpt_dir,
        async_checkpoint=False), preemption=guard)
    pipe.close()
    assert r.preempted and r.steps_run == 1
    assert latest_step(ckpt_dir) == 1


def test_loss_decreases_on_learnable_data():
    init, step_fn, make = _tiny_setup()
    pipe = StatelessPipeline(make, seed=2)
    r = run_training(init, step_fn, pipe, TrainLoopConfig(total_steps=30))
    pipe.close()
    first = np.mean(r.losses[:5])
    last = np.mean(r.losses[-5:])
    assert last < first, (first, last)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_schedule():
    opt = AdamW(OptimizerConfig(lr=1e-3, warmup_steps=10, decay_steps=100))
    assert float(opt.learning_rate(jnp.asarray(0))) == 0.0
    assert float(opt.learning_rate(jnp.asarray(10))) == pytest.approx(1e-3, rel=1e-3)
    assert float(opt.learning_rate(jnp.asarray(100))) == pytest.approx(1e-4, rel=1e-2)


def test_adamw_clipping():
    opt = AdamW(OptimizerConfig(clip_norm=1.0, lr=1.0, weight_decay=0.0))
    params = {"w": jnp.zeros(4)}
    st = opt.init(params)
    huge = {"w": jnp.full(4, 1e6)}
    new_p, _ = opt.update(params, huge, st, jnp.asarray(0))
    # clipped: update magnitude bounded by lr * m_hat/sqrt(v_hat) ~ lr
    assert float(jnp.abs(new_p["w"]).max()) < 5.0
