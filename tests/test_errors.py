"""Tests: the consolidated typed-error surface (``repro/errors.py``) —
every intentional engine error derives from ``ReproError``, stdlib bases
survive for old ``except`` clauses, and the pre-consolidation import
locations keep re-exporting the same classes."""

import pytest

import repro
from repro.errors import (
    GSQLCompileError,
    GSQLError,
    GSQLSyntaxError,
    MissingTableError,
    QueryTimeoutError,
    ReproError,
    ServerOverloadedError,
    TenantQuotaExceededError,
)


def test_everything_derives_from_repro_error():
    for exc in (GSQLError, GSQLSyntaxError, GSQLCompileError,
                QueryTimeoutError, ServerOverloadedError,
                TenantQuotaExceededError, MissingTableError):
        assert issubclass(exc, ReproError), exc


def test_stdlib_bases_survive_for_old_except_clauses():
    assert issubclass(QueryTimeoutError, TimeoutError)
    assert issubclass(ServerOverloadedError, RuntimeError)
    assert issubclass(TenantQuotaExceededError, ServerOverloadedError)
    assert issubclass(MissingTableError, RuntimeError)
    assert issubclass(GSQLSyntaxError, GSQLError)
    assert issubclass(GSQLCompileError, GSQLError)


def test_gsql_error_position_rendering():
    assert "line 3, col 7" in str(GSQLSyntaxError("bad token", 3, 7))
    assert str(GSQLCompileError("no such column")) == "no such column"


def test_old_locations_reexport_the_same_classes():
    from repro.core import catalog, plan
    from repro.gsql import errors as gsql_errors
    from repro.serving import server

    assert plan.QueryTimeoutError is QueryTimeoutError
    assert catalog.MissingTableError is MissingTableError
    assert server.ServerOverloadedError is ServerOverloadedError
    assert server.TenantQuotaExceededError is TenantQuotaExceededError
    assert gsql_errors.GSQLError is GSQLError
    assert gsql_errors.GSQLSyntaxError is GSQLSyntaxError
    assert gsql_errors.GSQLCompileError is GSQLCompileError


def test_package_level_exports():
    for name in ("ReproError", "GSQLError", "GSQLSyntaxError",
                 "GSQLCompileError", "QueryTimeoutError",
                 "ServerOverloadedError", "TenantQuotaExceededError",
                 "MissingTableError"):
        assert getattr(repro, name) is getattr(
            __import__("repro.errors", fromlist=[name]), name)


def test_one_except_catches_the_engine():
    with pytest.raises(ReproError):
        raise TenantQuotaExceededError("quota")
    with pytest.raises(ReproError):
        raise GSQLSyntaxError("parse", 1, 1)
