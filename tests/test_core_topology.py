"""Tests: transformed IDs, IDM, edge lists, topology build + materialization."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.edge_list import EdgeList
from repro.core.topology import GraphTopology
from repro.core.types import (
    DANGLING_FILE_ID,
    GraphSchema,
    VSet,
    make_transformed,
    split_transformed,
)
from repro.core.vertex_idm import VertexIDM
from repro.data.ldbc import generate_ldbc, ldbc_graph_schema
from repro.lakehouse.objectstore import ObjectStore, StoreConfig
from repro.lakehouse.table import LakeCatalog


@pytest.fixture
def store(tmp_path):
    return ObjectStore(StoreConfig(root=str(tmp_path / "lake")))


@pytest.fixture
def ldbc(store):
    return generate_ldbc(store, scale_factor=0.004, n_files=3, row_group_rows=256)


# ---------------------------------------------------------------------------
# transformed IDs
# ---------------------------------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(
    st.integers(min_value=0, max_value=2**31 - 1),
    st.integers(min_value=0, max_value=2**31 - 1),
)
def test_transformed_id_roundtrip(file_id, row):
    tid = make_transformed(file_id, row)
    f, r = split_transformed(tid)
    assert int(f) == file_id and int(r) == row


def test_transformed_id_vectorized():
    fids = np.array([1, 2, 3, DANGLING_FILE_ID])
    rows = np.array([0, 5, 100, 7])
    f, r = split_transformed(make_transformed(fids, rows))
    np.testing.assert_array_equal(f, fids)
    np.testing.assert_array_equal(r, rows)


# ---------------------------------------------------------------------------
# Vertex IDM
# ---------------------------------------------------------------------------

def test_idm_translate_and_dangling():
    idm = VertexIDM()
    idm.insert_batch("V", np.array([100, 200, 300]), file_id=1)
    idm.insert_batch("V", np.array([400, 500]), file_id=2)
    idm.freeze()
    tids = idm.translate("V", np.array([300, 400, 100]))
    f, r = split_transformed(tids)
    np.testing.assert_array_equal(f, [1, 2, 1])
    np.testing.assert_array_equal(r, [2, 0, 0])
    # dangling id gets file 0 + counter row
    t2 = idm.translate("V", np.array([999, 999, 888]))
    f2, r2 = split_transformed(t2)
    np.testing.assert_array_equal(f2, [DANGLING_FILE_ID] * 3)
    assert r2[0] == r2[1] != r2[2]
    assert idm.n_dangling() == 2
    with pytest.raises(KeyError):
        idm.translate("V", np.array([777]), allow_dangling=False)


def test_idm_duplicate_pk_rejected():
    idm = VertexIDM()
    idm.insert_batch("V", np.array([1, 2]), file_id=1)
    idm.insert_batch("V", np.array([2, 3]), file_id=2)
    with pytest.raises(ValueError):
        idm.freeze()


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=10**9), min_size=1,
                max_size=200, unique=True))
def test_idm_property_bijective(raw_ids):
    raw = np.array(raw_ids, dtype=np.int64)
    idm = VertexIDM()
    half = len(raw) // 2
    idm.insert_batch("V", raw[:half], file_id=1)
    idm.insert_batch("V", raw[half:], file_id=2)
    idm.freeze()
    tids = idm.translate("V", raw)
    assert len(np.unique(tids)) == len(raw)  # injective
    f, r = split_transformed(tids)
    np.testing.assert_array_equal(f[:half], 1)
    np.testing.assert_array_equal(r[:half], np.arange(half))


# ---------------------------------------------------------------------------
# edge lists
# ---------------------------------------------------------------------------

def test_edge_list_serialization_roundtrip():
    src = np.arange(100, dtype=np.int64) << 32
    dst = (np.arange(100, dtype=np.int64) % 7) << 32 | 3
    el = EdgeList("E", "f.col", src, dst, np.arange(100), np.arange(100) % 7,
                  row_group_rows=[60, 40])
    back = EdgeList.from_bytes(el.to_bytes())
    assert back.edge_type == "E" and back.file_key == "f.col"
    np.testing.assert_array_equal(back.src_tids, src)
    np.testing.assert_array_equal(back.dst_dense, el.dst_dense)
    assert [p.n_rows for p in back.portions] == [60, 40]


def test_edge_list_portion_stats_and_pruning():
    src_dense = np.array([0, 1, 2, 10, 11, 12], dtype=np.int64)
    dst_dense = np.array([5, 5, 5, 20, 20, 20], dtype=np.int64)
    el = EdgeList("E", "f", src_dense, dst_dense, src_dense, dst_dense, [3, 3])
    assert el.portions[0].src_min == 0 and el.portions[0].src_max == 2
    assert el.portions[1].src_min == 10 and el.portions[1].src_max == 12
    hit = el.portions_overlapping(0, 5, direction="out")
    assert [p.row_group for p in hit] == [0]
    hit_in = el.portions_overlapping(20, 20, direction="in")
    assert [p.row_group for p in hit_in] == [1]


# ---------------------------------------------------------------------------
# topology build over a real lakehouse
# ---------------------------------------------------------------------------

def test_topology_build_counts(store, ldbc):
    topo = GraphTopology(ldbc.schema)
    topo.build(store, LakeCatalog(store))
    assert topo.n_real_vertices("Person") == ldbc.n_persons
    assert topo.n_real_vertices("Comment") == ldbc.n_comments
    assert topo.n_edges("HasCreator") == ldbc.n_comments
    assert topo.n_edges() == ldbc.n_edges
    assert "idm_build_s" in topo.timings and "edge_list_build_s" in topo.timings


def test_topology_row_alignment(store, ldbc):
    """Edge-list entries must align row-for-row with edge attribute columns."""
    from repro.lakehouse.columnfile import read_columns

    topo = GraphTopology(ldbc.schema)
    topo.build(store, LakeCatalog(store))
    el = topo.edge_lists["HasCreator"][0]
    meta = topo.edge_file_metas[el.file_key]
    raw = read_columns(store, meta, ["src", "dst"])
    # re-translate raw FKs -> dense and compare with the edge list
    tids = topo.idm.translate("Comment", raw["src"])
    np.testing.assert_array_equal(topo.tid_to_dense("Comment", tids), el.src_dense)
    tids_d = topo.idm.translate("Person", raw["dst"])
    np.testing.assert_array_equal(topo.tid_to_dense("Person", tids_d), el.dst_dense)


def test_topology_dense_roundtrip(store, ldbc):
    topo = GraphTopology(ldbc.schema)
    topo.build(store, LakeCatalog(store))
    dense = np.arange(topo.n_real_vertices("Person"), dtype=np.int64)
    fids, rows = topo.dense_to_file_row("Person", dense)
    back = topo.tid_to_dense("Person", make_transformed(fids, rows))
    np.testing.assert_array_equal(back, dense)


def test_topology_materialize_and_reload(store, ldbc):
    topo = GraphTopology(ldbc.schema)
    topo.build(store, LakeCatalog(store))
    topo.materialize(store)
    assert GraphTopology.is_materialized(store)

    topo2 = GraphTopology(ldbc_graph_schema())
    topo2.load_materialized(store, LakeCatalog(store))
    assert topo2.n_edges() == topo.n_edges()
    for ename in topo.edge_lists:
        np.testing.assert_array_equal(
            np.sort(np.concatenate([el.src_dense for el in topo.edge_lists[ename]])),
            np.sort(np.concatenate([el.src_dense for el in topo2.edge_lists[ename]])),
        )


def test_topology_incremental_edge_update(store, ldbc):
    topo = GraphTopology(ldbc.schema)
    topo.build(store, LakeCatalog(store))
    before = topo.n_edges("Knows")
    n_lists_before = len(topo.edge_lists["Knows"])

    # append a new edge file to the Knows table
    lake = LakeCatalog(store)
    t = lake.table("Person_Knows_Person")
    person_raw = topo.idm.raw_ids("Person")
    new = {
        "src": person_raw[:10],
        "dst": person_raw[10:20],
        "creationDate": np.full(10, 20230101, dtype=np.int64),
    }
    t.append_files([new])
    added, removed = topo.refresh_edges(store, lake, "Knows")
    assert (added, removed) == (1, 0)
    assert topo.n_edges("Knows") == before + 10

    # delete one original file -> only its edge list drops
    victim = t.data_files()[0]
    t.delete_file(victim)
    added, removed = topo.refresh_edges(store, lake, "Knows")
    assert removed == 1 and added == 0
    assert len(topo.edge_lists["Knows"]) == n_lists_before


def test_file_filter_sharding(store, ldbc):
    """file_filter restricts a node to its own edge files (distributed build)."""
    topo_a = GraphTopology(ldbc.schema)
    topo_a.build(store, LakeCatalog(store), file_filter=lambda k, i: i % 2 == 0)
    topo_b = GraphTopology(ldbc_graph_schema())
    topo_b.build(store, LakeCatalog(store), file_filter=lambda k, i: i % 2 == 1)
    full = GraphTopology(ldbc_graph_schema())
    full.build(store, LakeCatalog(store))
    for ename in full.edge_lists:
        assert topo_a.n_edges(ename) + topo_b.n_edges(ename) == full.n_edges(ename)


# ---------------------------------------------------------------------------
# VSet algebra
# ---------------------------------------------------------------------------

def test_vset_algebra():
    a = VSet.from_dense_ids("V", 10, [1, 2, 3])
    b = VSet.from_dense_ids("V", 10, [3, 4])
    assert a.union(b).ids().tolist() == [1, 2, 3, 4]
    assert a.intersect(b).ids().tolist() == [3]
    assert a.minus(b).ids().tolist() == [1, 2]
    assert a.min_max() == (1, 3)
    with pytest.raises(ValueError):
        a.union(VSet.from_dense_ids("W", 10, [1]))
