"""Tests: engine startup paths, primitives, query layer, distributed 2-pass."""

import numpy as np
import pytest

from repro.core.distributed import DistributedGraphLake
from repro.core.engine import GraphLakeEngine
from repro.core.catalog import GraphCatalog
from repro.core.query import Query, accum_sum, eq, ge, gt
from repro.core.types import VSet
from repro.data.ldbc import generate_ldbc, ldbc_graph_schema
from repro.lakehouse.columnfile import read_columns, read_footer
from repro.lakehouse.objectstore import ObjectStore, StoreConfig
from repro.lakehouse.table import LakeCatalog


@pytest.fixture
def store(tmp_path):
    return ObjectStore(StoreConfig(root=str(tmp_path / "lake")))


@pytest.fixture
def ldbc(store):
    return generate_ldbc(store, scale_factor=0.004, n_files=3, row_group_rows=256)


def _oracle_tables(store, schema):
    """Load every table fully via the substrate, as plain dicts (oracle)."""
    lake = LakeCatalog(store)
    out = {}
    for name in lake.list_tables():
        t = lake.table(name)
        parts = {}
        for key in t.data_files():
            meta = read_footer(store, key)
            cols = read_columns(store, meta, meta.columns)
            for c, arr in cols.items():
                parts.setdefault(c, []).append(arr)
        out[name] = {c: np.concatenate(v) for c, v in parts.items()}
    return out


# ---------------------------------------------------------------------------
# startup paths
# ---------------------------------------------------------------------------

def test_first_and_second_connection(store, ldbc):
    with GraphLakeEngine(store, ldbc.schema) as eng:
        eng.startup()
        assert eng.startup_mode == "first_connection"
        n_edges = eng.topology.n_edges()
    with GraphLakeEngine(store, ldbc_graph_schema()) as eng2:
        eng2.startup()
        assert eng2.startup_mode == "second_connection"
        assert eng2.topology.n_edges() == n_edges
        assert "load_topology_s" in eng2.topology.timings


# ---------------------------------------------------------------------------
# VertexMap
# ---------------------------------------------------------------------------

def test_vertex_map_filter_matches_oracle(store, ldbc):
    oracle = _oracle_tables(store, ldbc.schema)
    with GraphLakeEngine(store, ldbc.schema) as eng:
        eng.startup()
        vset, _ = eng.vertex_map(
            eng.all_vertices("Person"), columns=["gender"],
            filter_fn=lambda fr: np.asarray([g == "Female" for g in fr["gender"]]),
        )
        expect = sum(1 for g in oracle["Person"]["gender"] if g == "Female")
        assert vset.size() == expect


def test_vertex_map_map_fn(store, ldbc):
    with GraphLakeEngine(store, ldbc.schema) as eng:
        eng.startup()
        _, vals = eng.vertex_map(
            eng.all_vertices("Comment"), columns=["length"],
            map_fn=lambda fr: fr["length"] * 2,
        )
        assert vals is not None and len(vals) == ldbc.n_comments


# ---------------------------------------------------------------------------
# EdgeScan
# ---------------------------------------------------------------------------

def test_edge_scan_full_frontier_matches_oracle(store, ldbc):
    oracle = _oracle_tables(store, ldbc.schema)
    with GraphLakeEngine(store, ldbc.schema) as eng:
        eng.startup()
        frame = eng.edge_scan(eng.all_vertices("Comment"), "HasCreator")
        assert len(frame) == len(oracle["Comment_HasCreator_Person"]["src"])


def test_edge_scan_bidirectional_consistency(store, ldbc):
    with GraphLakeEngine(store, ldbc.schema) as eng:
        eng.startup()
        out_frame = eng.edge_scan(eng.all_vertices("Comment"), "HasCreator", "out")
        in_frame = eng.edge_scan(eng.all_vertices("Person"), "HasCreator", "in")
        # same edge set, roles swapped
        assert len(out_frame) == len(in_frame)
        a = np.sort(out_frame.u * (1 << 32) + out_frame.v)
        b = np.sort(in_frame.v * (1 << 32) + in_frame.u)
        np.testing.assert_array_equal(a, b)


def test_edge_scan_frontier_restriction(store, ldbc):
    with GraphLakeEngine(store, ldbc.schema) as eng:
        eng.startup()
        n_c = eng.topology.n_vertices("Comment")
        some = VSet.from_dense_ids("Comment", n_c, np.arange(10))
        frame = eng.edge_scan(some, "HasCreator")
        assert len(frame) == 10  # HasCreator is 1 per comment
        assert set(np.unique(frame.u)) <= set(range(10))


def test_edge_scan_cross_entity_predicate(store, ldbc):
    oracle = _oracle_tables(store, ldbc.schema)
    with GraphLakeEngine(store, ldbc.schema) as eng:
        eng.startup()
        frame = eng.edge_scan(
            eng.all_vertices("Comment"), "HasCreator",
            edge_columns=["creationDate"], v_columns=["gender"],
            edge_filter=lambda fr: (fr["e.creationDate"] > 20150101)
            & np.asarray([g == "Female" for g in fr["v.gender"]]),
        )
        # oracle join
        hc = oracle["Comment_HasCreator_Person"]
        pid_to_gender = dict(zip(oracle["Person"]["id"].tolist(),
                                 oracle["Person"]["gender"].tolist()))
        expect = sum(
            1 for d, p in zip(hc["creationDate"], hc["dst"])
            if d > 20150101 and pid_to_gender[int(p)] == "Female"
        )
        assert len(frame) == expect


# ---------------------------------------------------------------------------
# Query layer (the paper's running example, §6)
# ---------------------------------------------------------------------------

def test_paper_example_query(store, ldbc):
    oracle = _oracle_tables(store, ldbc.schema)
    with GraphLakeEngine(store, ldbc.schema) as eng:
        eng.startup()
        res = (
            Query(eng)
            .vertices("Tag", where=eq("name", "Music"))
            .hop("HasTag", direction="in")
            .hop("HasCreator", direction="out",
                 edge_where=gt("creationDate", 20100101),
                 target_where=eq("gender", "Female"),
                 accum=accum_sum("cnt", 1.0))
            .run()
        )
        # oracle: comments with tag Music -> creators female, created > date
        tags = oracle["Tag"]
        music_tags = set(tags["id"][np.asarray([n == "Music" for n in tags["name"]])].tolist())
        ht = oracle["Comment_HasTag_Tag"]
        music_comments = set(ht["src"][np.isin(ht["dst"], list(music_tags))].tolist())
        hc = oracle["Comment_HasCreator_Person"]
        pid_to_gender = dict(zip(oracle["Person"]["id"].tolist(),
                                 oracle["Person"]["gender"].tolist()))
        per_person = {}
        for s, d, date in zip(hc["src"], hc["dst"], hc["creationDate"]):
            if int(s) in music_comments and date > 20100101 \
                    and pid_to_gender[int(d)] == "Female":
                per_person[int(d)] = per_person.get(int(d), 0) + 1
        assert res.accumulators["cnt"].sum() == sum(per_person.values())
        assert res.vset.size() == len(per_person)


def test_query_accum_column_value(store, ldbc):
    with GraphLakeEngine(store, ldbc.schema) as eng:
        eng.startup()
        res = (
            Query(eng)
            .vertices("Comment")
            .hop("HasCreator", direction="out",
                 accum=accum_sum("total_len", "u.length"))
            .run()
        )
        oracle = _oracle_tables(store, ldbc.schema)
        assert res.accumulators["total_len"].sum() == pytest.approx(
            float(oracle["Comment"]["length"].sum())
        )


# ---------------------------------------------------------------------------
# catalog sync
# ---------------------------------------------------------------------------

def test_graph_catalog_sync(store, ldbc):
    with GraphLakeEngine(store, ldbc.schema) as eng:
        eng.startup()
        cat = GraphCatalog(store, eng.schema, eng.topology)
        assert "Knows" in cat.mapping()["edges"]
        r0 = cat.sync()
        assert r0.edge_lists_added == 0
        t = LakeCatalog(store).table("Person_Knows_Person")
        raw = eng.topology.idm.raw_ids("Person")
        t.append_files([{
            "src": raw[:5], "dst": raw[5:10],
            "creationDate": np.full(5, 20230101, dtype=np.int64),
        }])
        r1 = cat.sync()
        assert r1.edge_lists_added == 1


def test_graph_catalog_missing_table_raises(store, ldbc):
    """A schema mapped to a nonexistent table is a configuration error —
    the old bare ``except Exception`` silently pinned snapshot -1 instead."""
    from repro.core.catalog import MissingTableError
    from repro.core.topology import GraphTopology
    from repro.core.types import GraphSchema

    bad = GraphSchema()
    bad.add_vertex_type("Ghost", table="NoSuchTable", primary_key="id")
    with pytest.raises(MissingTableError):
        GraphCatalog(store, bad, GraphTopology(bad))


def test_graph_catalog_empty_table_is_legitimate(store, ldbc):
    """A table that exists but has no snapshots yet pins -1, no raise."""
    from repro.core.topology import GraphTopology
    from repro.core.types import GraphSchema
    from repro.lakehouse.table import ColumnSpec, TableSchema

    LakeCatalog(store).table("Fresh").create(TableSchema("Fresh", [
        ColumnSpec("id", "int64", role="primary_key")]))
    schema = GraphSchema()
    schema.add_vertex_type("Fresh", table="Fresh", primary_key="id")
    cat = GraphCatalog(store, schema, GraphTopology(schema))
    assert cat._vertex_snapshots["Fresh"] == -1


def test_graph_catalog_sync_promotes_to_epochs(store, ldbc):
    """With an EpochManager attached, sync() is the epoch-publishing
    advance(): it diffs consistently and reports in the legacy shape."""
    with GraphLakeEngine(store, ldbc.schema, materialize_topology=False) as eng:
        eng.startup()
        cat = GraphCatalog(store, eng.schema, eng.topology, epochs=eng.epochs)
        assert cat.sync() == __import__(
            "repro.core.catalog", fromlist=["SyncReport"]).SyncReport()
        e0 = eng.current_epoch()
        raw = eng.topology.idm.raw_ids("Person")
        LakeCatalog(store).table("Person_Knows_Person").append_files([{
            "src": raw[:5], "dst": raw[5:10],
            "creationDate": np.full(5, 20230101, dtype=np.int64),
        }])
        r = cat.sync()
        assert r.edge_lists_added == 1 and not r.vertex_changes_detected
        assert eng.current_epoch().epoch_id == e0.epoch_id + 1


# ---------------------------------------------------------------------------
# distributed two-pass EdgeScan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_partitions", [2, 3])
def test_distributed_matches_single_node(store, ldbc, n_partitions):
    # single-node reference
    with GraphLakeEngine(store, ldbc.schema, materialize_topology=False) as eng:
        eng.startup()
        res = (
            Query(eng)
            .vertices("Comment")
            .hop("HasCreator", direction="out",
                 edge_where=gt("creationDate", 20150101),
                 target_where=eq("gender", "Female"),
                 accum=accum_sum("cnt", 1.0))
            .run()
        )
        ref_accum = res.accumulators["cnt"]

    dist = DistributedGraphLake(store, ldbc_graph_schema(), n_partitions=n_partitions)
    try:
        dist.startup()
        # partitions cover all edges exactly once
        total = sum(e.topology.n_edges("HasCreator") for e in dist.engines)
        assert total == ldbc.n_comments

        frontier = dist.engines[0].all_vertices("Comment")
        nxt, accum = dist.edge_scan_accumulate(
            frontier, "HasCreator", "out",
            edge_columns=["creationDate"],
            v_columns=["gender"],
            edge_filter=lambda fr: fr["e.creationDate"] > 20150101,
            v_filter=lambda fr: np.asarray([g == "Female" for g in fr["v.gender"]]),
            accum_name="cnt", accum_op="sum", accum_value=1.0,
        )
        np.testing.assert_allclose(accum, ref_accum)
        assert dist.net.requests > 0  # remote fetches actually happened
        assert nxt.size() == int((ref_accum > 0).sum())
    finally:
        dist.close()
