"""Tests: graph-aware cache units, sweep-clock manager, prefetcher."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.cache.manager import CacheConfig, CacheManager
from repro.core.cache.prefetch import Prefetcher
from repro.core.cache.units import ChunkRef, EdgeCacheUnit, NaiveChunkReader, VertexCacheUnit
from repro.core.topology import GraphTopology
from repro.core.types import VSet
from repro.data.ldbc import generate_ldbc
from repro.lakehouse.columnfile import write_column_file
from repro.lakehouse.encoding import Encoding, encode_column
from repro.lakehouse.objectstore import ObjectStore, StoreConfig
from repro.lakehouse.table import LakeCatalog


@pytest.fixture
def store(tmp_path):
    return ObjectStore(StoreConfig(root=str(tmp_path / "lake")))


def _chunk(arr, encoding=Encoding.PLAIN):
    return encode_column(np.asarray(arr), encoding)


# ---------------------------------------------------------------------------
# vertex cache unit: contiguous-prefix invariant
# ---------------------------------------------------------------------------

def test_vertex_unit_prefix_extension():
    arr = np.arange(1000, dtype=np.int64) * 3
    u = VertexCacheUnit(ChunkRef("f", "c", 0), _chunk(arr), 1000)
    got = u.read(np.array([99]))
    assert got[0] == 297
    assert u.decoded_prefix == 100        # decoded exactly through row 99
    first_ops = u.decode_ops
    # request inside the prefix: no extra decoding
    u.read(np.array([5, 50, 99]))
    assert u.decode_ops == first_ops
    # request beyond: prefix extends, intermediate rows populated
    u.read(np.array([300]))
    assert u.decoded_prefix == 301
    np.testing.assert_array_equal(u.read(np.array([150, 250])), [450, 750])


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=499), min_size=1, max_size=40))
def test_vertex_unit_property_matches_source(requests):
    arr = (np.arange(500, dtype=np.int64) ** 2) % 1013
    u = VertexCacheUnit(ChunkRef("f", "c", 0), _chunk(arr), 500)
    for r in requests:
        assert u.read(np.array([r]))[0] == arr[r]
        # invariant: decoded region is always a contiguous prefix
        assert u.decoded_prefix >= r + 1


def test_vertex_unit_strings():
    arr = np.array([f"s{i}" for i in range(64)], dtype=object)
    u = VertexCacheUnit(ChunkRef("f", "c", 0), _chunk(arr, Encoding.DICTIONARY), 64)
    assert u.read(np.array([10, 63])).tolist() == ["s10", "s63"]


def test_vertex_unit_spill_restore():
    arr = np.arange(100, dtype=np.int64)
    u = VertexCacheUnit(ChunkRef("f", "c", 0), _chunk(arr), 100)
    u.read(np.array([40]))
    values, upto = u.export_decoded()
    u2 = VertexCacheUnit(ChunkRef("f", "c", 0), _chunk(arr), 100)
    u2.import_decoded(values, upto)
    assert u2.decoded_prefix == 41
    ops_before = u2.decode_ops
    assert u2.read(np.array([40]))[0] == 40
    assert u2.decode_ops == ops_before  # restored prefix avoids re-decode


# ---------------------------------------------------------------------------
# edge cache unit: sliding window
# ---------------------------------------------------------------------------

def test_edge_unit_sliding_window():
    arr = np.arange(10_000, dtype=np.float64)
    u = EdgeCacheUnit(ChunkRef("f", "c", 0), _chunk(arr), 10_000, window=64)
    assert u.read(np.array([50]))[0] == 50.0
    ops1 = u.decode_ops
    assert u.read(np.array([55]))[0] == 55.0   # inside window: free
    assert u.decode_ops == ops1
    assert u.read(np.array([500]))[0] == 500.0  # outside window: advances
    assert u.decode_ops > ops1


def test_edge_unit_batch_reads_match():
    rng = np.random.default_rng(3)
    arr = rng.standard_normal(5000)
    u = EdgeCacheUnit(ChunkRef("f", "c", 0), _chunk(arr), 5000, window=128)
    idx = np.sort(rng.integers(0, 5000, size=300))
    np.testing.assert_array_equal(u.read(idx), arr[idx])


def test_naive_reader_redecodes():
    arr = np.arange(1000, dtype=np.int64)
    u = NaiveChunkReader(ChunkRef("f", "c", 0), _chunk(arr), 1000)
    u.read(np.array([500]))
    u.read(np.array([500]))
    assert u.decode_ops == 1002  # decoded twice — that's the Fig 16 baseline


# ---------------------------------------------------------------------------
# cache manager: sweep-clock priorities + two tiers
# ---------------------------------------------------------------------------

def _file_with_columns(store, key, n=256, n_cols=4):
    cols = {f"c{i}": np.arange(n, dtype=np.int64) + i for i in range(n_cols)}
    return write_column_file(store, key, cols, row_group_rows=n)


def test_manager_hit_miss_and_reuse(store):
    meta = _file_with_columns(store, "t/f0.col")
    mgr = CacheManager(store)
    ref = ChunkRef("t/f0.col", "c0", 0)
    u1 = mgr.get_unit(ref, meta, "vertex")
    u2 = mgr.get_unit(ref, meta, "vertex")
    assert u1 is u2
    assert mgr.stats["hits"] == 1 and mgr.stats["misses"] == 1
    assert mgr.stats["lake_fetches"] == 1


def test_manager_eviction_prefers_edges(store):
    meta = _file_with_columns(store, "t/f0.col", n=2048, n_cols=8)
    budget = 4 * (2048 * 8 + 2100)  # roughly 4 units
    mgr = CacheManager(store, CacheConfig(memory_budget_bytes=budget))
    vrefs = [ChunkRef("t/f0.col", f"c{i}", 0) for i in range(2)]
    erefs = [ChunkRef("t/f0.col", f"c{i}", 0) for i in range(2, 8)]
    for r in vrefs:
        mgr.get_unit(r, meta, "vertex").read_all()
    for r in erefs:
        mgr.get_unit(r, meta, "edge").read_all()
    resident = mgr.resident_keys()
    # vertex units (priority 3) should survive the clock preferentially
    assert all(r.cache_key() in resident for r in vrefs)
    assert mgr.stats["evictions"] > 0


def test_manager_vertex_flush_and_disk_hit(store):
    meta = _file_with_columns(store, "t/f0.col", n=4096, n_cols=6)
    mgr = CacheManager(store, CacheConfig(memory_budget_bytes=2 * (4096 * 8 + 4200)))
    refs = [ChunkRef("t/f0.col", f"c{i}", 0) for i in range(6)]
    for r in refs:
        mgr.get_unit(r, meta, "vertex").read_all()
    assert mgr.stats["vertex_flushes"] > 0
    # re-admitting a flushed unit restores its decoded prefix from disk
    flushed = [r for r in refs if r.cache_key() not in mgr.resident_keys()]
    assert flushed
    u = mgr.get_unit(flushed[0], meta, "vertex")
    assert u.decoded_prefix == 4096  # restored, not re-decoded


def test_manager_pinned_units_never_evicted(store):
    meta = _file_with_columns(store, "t/f0.col", n=2048, n_cols=8)
    mgr = CacheManager(store, CacheConfig(memory_budget_bytes=3 * (2048 * 8 + 2100)))
    pinned_ref = ChunkRef("t/f0.col", "c0", 0)
    pinned = mgr.get_unit(pinned_ref, meta, "edge", pin=True)
    pinned.read_all()
    for i in range(1, 8):
        mgr.get_unit(ChunkRef("t/f0.col", f"c{i}", 0), meta, "edge").read_all()
    assert pinned_ref.cache_key() in mgr.resident_keys()
    mgr.unpin(pinned)


def test_disk_bytes_stable_across_evict_readmit_cycles(store):
    # regression: get_unit used to pop a spilled decoded entry without
    # decrementing _disk_bytes or dropping its _disk_order entry, so the
    # accounting drifted upward every evict/re-admit cycle and eventually
    # forced premature disk trims
    meta = _file_with_columns(store, "t/f0.col", n=4096, n_cols=6)
    mgr = CacheManager(store, CacheConfig(memory_budget_bytes=2 * (4096 * 8 + 4200)))
    refs = [ChunkRef("t/f0.col", f"c{i}", 0) for i in range(6)]

    def cycle():
        for r in refs:
            mgr.get_unit(r, meta, "vertex").read_all()

    for cyc in range(6):
        cycle()
        raw_bytes = sum(len(b) for b in mgr._disk_raw.values())
        decoded_bytes = sum(e[2] for e in mgr._disk_decoded.values())
        # accounting always matches what actually lives on the tier — the
        # old code drifted upward here on every evict/re-admit cycle
        assert mgr._disk_bytes == raw_bytes + decoded_bytes, cyc
        assert len(mgr._disk_decoded) <= len(refs)
        # order list carries no stale decoded entries
        live = {"D:" + k for k in mgr._disk_decoded}
        assert {k for k in mgr._disk_order if k.startswith("D:")} == live
    assert mgr.stats["vertex_flushes"] > 0
    # bounded by 6 raw chunks + 6 fully-decoded arrays, with headroom
    assert mgr._disk_bytes <= 6 * (4096 * 8 + 4300) * 2


def test_disk_put_decoded_duplicate_key_no_double_count(store):
    meta = _file_with_columns(store, "t/f0.col", n=256)
    mgr = CacheManager(store)
    u = mgr.get_unit(ChunkRef("t/f0.col", "c0", 0), meta, "vertex")
    u.read_all()
    values, upto = u.export_decoded()
    mgr._disk_put_decoded("k", values, upto)
    once = mgr._disk_bytes
    mgr._disk_put_decoded("k", values, upto)
    assert mgr._disk_bytes == once
    assert list(mgr._disk_order).count("D:k") == 1


def test_manager_drop_memory_keeps_disk(store):
    meta = _file_with_columns(store, "t/f0.col")
    mgr = CacheManager(store)
    mgr.get_unit(ChunkRef("t/f0.col", "c0", 0), meta, "vertex").read_all()
    fetches = mgr.stats["lake_fetches"]
    mgr.drop_memory()
    mgr.get_unit(ChunkRef("t/f0.col", "c0", 0), meta, "vertex")
    assert mgr.stats["lake_fetches"] == fetches  # disk tier served it


# ---------------------------------------------------------------------------
# prefetcher: frontier Min-Max + edge-list stats pruning
# ---------------------------------------------------------------------------

def test_prefetcher_prunes_by_frontier(store):
    generate_ldbc(store, scale_factor=0.004, n_files=2, row_group_rows=128)
    from repro.data.ldbc import ldbc_graph_schema

    topo = GraphTopology(ldbc_graph_schema())
    topo.build(store, LakeCatalog(store))
    mgr = CacheManager(store)
    pf = Prefetcher(mgr, topo, pool=None)

    n_p = topo.n_vertices("Person")
    narrow = VSet.from_dense_ids("Person", n_p, [0, 1, 2])
    issued_narrow = pf.prefetch_vertices(narrow, ["gender"])
    wide = VSet.full("Person", n_p)
    issued_wide = pf.prefetch_vertices(wide, ["gender"])
    assert 0 < issued_narrow < issued_wide

    n_c = topo.n_vertices("Comment")
    small = VSet.from_dense_ids("Comment", n_c, [0, 1])
    pf2 = Prefetcher(mgr, topo, pool=None)
    pf2.prefetch_edges(small, "HasCreator", ["creationDate"], direction="out")
    # edge tables are sorted by src -> portion stats should prune something
    assert pf2.stats["pruned_portions"] > 0
