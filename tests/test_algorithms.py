"""Tests: the five Table-2 graph algorithms against numpy oracles."""

import numpy as np
import pytest

from repro.core.algorithms import bfs, cdlp, lcc, pagerank, wcc
from repro.core.engine import GraphLakeEngine
from repro.data.graph500 import generate_graph500, graph500_schema
from repro.lakehouse.objectstore import ObjectStore, StoreConfig


@pytest.fixture(scope="module")
def engine(tmp_path_factory):
    root = tmp_path_factory.mktemp("lake")
    store = ObjectStore(StoreConfig(root=str(root)))
    schema = generate_graph500(store, scale=8, edge_factor=8, n_files=3,
                               row_group_rows=2048)
    eng = GraphLakeEngine(store, schema)
    eng.startup()
    yield eng
    eng.close()


def _edges(engine):
    return engine.concat_edges("Edge")


# ---------------------------------------------------------------------------
# oracles
# ---------------------------------------------------------------------------

def _pagerank_oracle(src, dst, n, damping=0.85, iters=20):
    deg = np.bincount(src, minlength=n).astype(np.float64)
    r = np.full(n, 1.0 / n)
    for _ in range(iters):
        contrib = np.where(deg[src] > 0, r[src] / np.maximum(deg[src], 1), 0.0)
        agg = np.bincount(dst, weights=contrib, minlength=n)
        dangling = r[deg == 0].sum()
        r = (1 - damping) / n + damping * (agg + dangling / n)
    return r


def _wcc_oracle(src, dst, n):
    parent = np.arange(n)

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for s, d in zip(src.tolist(), dst.tolist()):
        rs, rd = find(s), find(d)
        if rs != rd:
            parent[max(rs, rd)] = min(rs, rd)
    return np.array([find(i) for i in range(n)])


def _bfs_oracle(src, dst, n, source):
    from collections import deque
    adj = [[] for _ in range(n)]
    for s, d in zip(src.tolist(), dst.tolist()):
        adj[s].append(d)
    depth = np.full(n, -1, dtype=np.int64)
    depth[source] = 0
    q = deque([source])
    while q:
        u = q.popleft()
        for v in adj[u]:
            if depth[v] < 0:
                depth[v] = depth[u] + 1
                q.append(v)
    return depth


def _lcc_oracle(src, dst, n):
    nbrs = [set() for _ in range(n)]
    for s, d in zip(src.tolist(), dst.tolist()):
        if s != d:
            nbrs[s].add(d)
            nbrs[d].add(s)
    out = np.zeros(n)
    for v in range(n):
        k = len(nbrs[v])
        if k < 2:
            continue
        links = 0
        for u in nbrs[v]:
            links += len(nbrs[v] & nbrs[u])
        out[v] = links / 2 / (k * (k - 1) / 2)
    return out


# ---------------------------------------------------------------------------
# tests
# ---------------------------------------------------------------------------

def test_pagerank_matches_oracle(engine):
    src, dst = _edges(engine)
    n = engine.topology.n_vertices("Node")
    got = pagerank(engine, "Edge", max_iters=20, tol=0.0)
    want = _pagerank_oracle(src, dst, n, iters=20)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=1e-9)
    assert got.sum() == pytest.approx(1.0, rel=1e-3)


def test_wcc_matches_oracle(engine):
    src, dst = _edges(engine)
    n = engine.topology.n_vertices("Node")
    got = wcc(engine, "Edge")
    want = _wcc_oracle(src, dst, n)
    # same partition: equal component labels up to renaming — both use min-id
    np.testing.assert_array_equal(got, want)


def test_bfs_matches_oracle(engine):
    src, dst = _edges(engine)
    n = engine.topology.n_vertices("Node")
    source = int(src[0])
    got = bfs(engine, "Edge", source, directed=True)
    want = _bfs_oracle(src, dst, n, source)
    np.testing.assert_array_equal(got, want)


def test_lcc_matches_oracle(engine):
    src, dst = _edges(engine)
    n = engine.topology.n_vertices("Node")
    got = lcc(engine, "Edge", block=512)
    want = _lcc_oracle(src, dst, n)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-9)


def test_cdlp_structure(engine):
    """CDLP: labels converge to community-ish assignments; every label is a
    vertex id present in the graph; deterministic across runs."""
    got1 = cdlp(engine, "Edge", iterations=5)
    got2 = cdlp(engine, "Edge", iterations=5)
    np.testing.assert_array_equal(got1, got2)
    n = engine.topology.n_vertices("Node")
    assert got1.min() >= 0 and got1.max() < n
    # fewer distinct labels than vertices (communities formed)
    assert len(np.unique(got1)) < n


def test_cdlp_two_cliques():
    """Two disjoint triangles must each converge to one label."""
    store = ObjectStore(StoreConfig(root="/tmp/cdlp_test_lake"))
    import shutil
    shutil.rmtree("/tmp/cdlp_test_lake", ignore_errors=True)
    store = ObjectStore(StoreConfig(root="/tmp/cdlp_test_lake"))
    from repro.lakehouse.writer import write_table
    from repro.lakehouse.table import ColumnSpec, TableSchema

    nodes = np.arange(6, dtype=np.int64)
    tri = [(0, 1), (1, 2), (2, 0), (3, 4), (4, 5), (5, 3)]
    src = np.array([a for a, b in tri] + [b for a, b in tri], dtype=np.int64)
    dst = np.array([b for a, b in tri] + [a for a, b in tri], dtype=np.int64)
    write_table(store, TableSchema("Node", [ColumnSpec("id", "int64", role="primary_key")]),
                {"id": nodes}, n_files=1)
    write_table(store, TableSchema("Node_Edge_Node", [
        ColumnSpec("src", "int64", role="foreign_key"),
        ColumnSpec("dst", "int64", role="foreign_key"),
        ColumnSpec("weight", "float64"),
    ]), {"src": src, "dst": dst, "weight": np.ones(len(src))}, n_files=1)
    with GraphLakeEngine(store, graph500_schema()) as eng:
        eng.startup()
        labels = cdlp(eng, "Edge", iterations=10)
    assert len(set(labels[:3].tolist())) == 1
    assert len(set(labels[3:6].tolist())) == 1
    assert labels[0] != labels[3]
