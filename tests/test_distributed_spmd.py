"""Multi-device SPMD tests, run in subprocesses with forced host devices
(device count locks at first jax init, so each scenario gets its own
process).  Covers: sharded-vs-local GNN parity (two-pass EdgeScan pattern),
ring gather grads, sharded embedding lookup parity, and a minimal dry-run
lower+compile on a small mesh."""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8, timeout: int = 600) -> str:
    prog = (
        f"import os\n"
        f"os.environ['XLA_FLAGS'] = "
        f"'--xla_force_host_platform_device_count={devices}'\n"
        + textwrap.dedent(code)
    )
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, "-c", prog], capture_output=True, text=True,
        timeout=timeout, env=env, cwd=REPO,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


@pytest.mark.slow
def test_gnn_sharded_matches_local():
    """The shard_map two-pass EdgeScan (gather + segment + psum_scatter) must
    be numerically identical to the single-device path — loss AND grads."""
    _run("""
    import os as _os
    _os.environ["REPRO_OPTS"] = ""          # exact parity: f32 wire
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_arch
    from repro.models.gnn.common import local_dist, sharded_dist
    from repro.models.gnn import GIN, GINConfig

    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    rng = np.random.default_rng(0)
    N, E = 64, 256          # divisible by 8 devices
    cfg = GINConfig(d_in=16, n_classes=4, task="node", n_layers=3, d_hidden=16)
    batch = dict(
        x=jnp.asarray(rng.standard_normal((N, 16)), jnp.float32),
        src=jnp.asarray(rng.integers(0, N, E), jnp.int32),
        dst=jnp.asarray(rng.integers(0, N, E), jnp.int32),
        edge_mask=jnp.ones(E, bool), node_mask=jnp.ones(N, bool),
        graph_ids=jnp.zeros(N, jnp.int32), n_graphs=8,
        graph_mask=jnp.ones(8, bool),
        labels=jnp.asarray(rng.integers(0, 4, N), jnp.int32),
        label_mask=jnp.ones(N, bool),
    )
    local = GIN(cfg, local_dist())
    params = local.init(jax.random.PRNGKey(0))
    l_loc = jax.jit(local.loss)(params, batch)
    g_loc = jax.jit(jax.grad(local.loss))(params, batch)

    shard = GIN(cfg, sharded_dist(mesh))
    l_sh = jax.jit(shard.loss)(params, batch)
    g_sh = jax.jit(jax.grad(shard.loss))(params, batch)

    np.testing.assert_allclose(float(l_loc), float(l_sh), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(g_loc), jax.tree.leaves(g_sh)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)
    print("GNN sharded == local OK")
    """)


@pytest.mark.slow
def test_ring_gather_matches_allgather():
    _run("""
    import os as _os
    _os.environ["REPRO_OPTS"] = ""          # exact parity: f32 wire
    import jax, jax.numpy as jnp, numpy as np
    from repro.models.gnn.common import sharded_dist
    mesh = jax.make_mesh((8,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    dist = sharded_dist(mesh)
    rng = np.random.default_rng(1)
    table = jnp.asarray(rng.standard_normal((128, 8)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 128, 64), jnp.int32)
    ring = jax.jit(lambda t: dist.gather_rows(t, idx, "ring"))(table)
    ag = jax.jit(lambda t: dist.gather_rows(t, idx, "allgather"))(table)
    np.testing.assert_allclose(np.asarray(ring), np.asarray(ag), rtol=1e-6)
    cot = jnp.asarray(rng.standard_normal((64, 8)), jnp.float32)
    g_r = jax.jit(jax.grad(lambda t: (dist.gather_rows(t, idx, "ring") * cot).sum()))(table)
    g_a = jax.grad(lambda t: (t[idx] * cot).sum())(table)
    np.testing.assert_allclose(np.asarray(g_r), np.asarray(g_a), rtol=1e-5)
    print("ring gather OK")
    """)


@pytest.mark.slow
def test_sharded_embedding_lookup_parity():
    """xDeepFM's shard_map table lookup (local masked take + psum) must match
    the single-device gather."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.models.recsys import XDeepFM, XDeepFMConfig
    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    cfg = XDeepFMConfig(vocab_sizes=tuple([64] * 6 + [32] * 2), n_multihot=2,
                        bag_size=4, cin_layers=(8, 8), mlp_dims=(16,),
                        embed_dim=8)
    rng = np.random.default_rng(0)
    B = 16
    f_single = cfg.n_fields - cfg.n_multihot
    offs = cfg.field_offsets
    batch = {
        "idx_single": jnp.asarray(np.stack(
            [rng.integers(0, cfg.vocab_sizes[f], B) + offs[f]
             for f in range(f_single)], 1), jnp.int32),
        "idx_multi": jnp.asarray(np.stack(
            [rng.integers(0, cfg.vocab_sizes[f_single + f], (B, 4))
             + offs[f_single + f] for f in range(cfg.n_multihot)], 1), jnp.int32),
        "w_multi": jnp.ones((B, cfg.n_multihot, 4), jnp.float32),
        "labels": jnp.asarray(rng.integers(0, 2, B), jnp.int32),
    }
    local = XDeepFM(cfg, mesh=None)
    params = local.init(jax.random.PRNGKey(0))
    sharded = XDeepFM(cfg, mesh=mesh)
    l_loc = jax.jit(local.loss)(params, batch)
    l_sh = jax.jit(sharded.loss)(params, batch)
    np.testing.assert_allclose(float(l_loc), float(l_sh), rtol=1e-5)
    g_loc = jax.jit(jax.grad(local.loss))(params, batch)
    g_sh = jax.jit(jax.grad(sharded.loss))(params, batch)
    for a, b in zip(jax.tree.leaves(g_loc), jax.tree.leaves(g_sh)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)
    print("sharded embedding OK")
    """)


@pytest.mark.slow
def test_lm_sharded_step_matches_single_device():
    """A reduced LM train step under a (2, 4) mesh with the production
    sharding rules must match the single-device result."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_arch
    from repro.distributed import sharding as shd
    from repro.distributed.meshctx import use_mesh
    arch = get_arch("qwen2-1.5b")
    cell = arch.shapes()[0]
    state = arch.init_state(jax.random.PRNGKey(0), cell, reduced=True)
    batch = arch.example_batch(cell, reduced=True)
    step = arch.make_step(cell, reduced=True)

    _, m1 = jax.jit(step)(jax.tree.map(jnp.copy, state), batch)

    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    state_sh = shd.lm_state_shardings(mesh, state)
    batch_sh = shd.lm_batch_shardings(mesh, batch)
    with use_mesh(mesh):
        _, m2 = jax.jit(step, in_shardings=(state_sh, batch_sh))(state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=2e-3)
    print("LM sharded step OK", float(m1["loss"]), float(m2["loss"]))
    """)


@pytest.mark.slow
def test_dryrun_compiles_reduced_cell():
    """dryrun machinery end-to-end on the real 512-device mesh for the
    cheapest cell (validates the deliverable-e path inside CI)."""
    _run("""
    import repro.launch.dryrun as dr
    rec = dr.run_cell("xdeepfm", "serve_p99", "pod", force=True)
    assert rec["status"] == "ok", rec
    assert rec["fits_hbm"], rec["per_device_bytes"]
    assert rec["roofline"]["dominant"] in ("compute", "memory", "collective")
    print("dryrun cell OK:", rec["roofline"]["dominant"])
    """, devices=512, timeout=900)


@pytest.mark.slow
def test_moe_ep_shardmap_parity():
    """The explicit expert-parallel dispatch (perf flag moe_ep) must match
    the pjit scatter path exactly — loss and grads (dropless sizes)."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.models.moe import MoEConfig, moe_apply, moe_init
    from repro.distributed.meshctx import use_mesh

    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    cfg = MoEConfig(d_model=32, d_ff_expert=16, n_experts=8, top_k=2, n_shared=1)
    params = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32), jnp.float32) * 0.5

    def loss(p, x):
        out, aux = moe_apply(p, cfg, x)
        return (out.astype(jnp.float32) ** 2).sum() + aux

    import os as _os
    _os.environ["REPRO_OPTS"] = ""
    l_ref = jax.jit(loss)(params, x)
    g_ref = jax.jit(jax.grad(loss))(params, x)
    _os.environ["REPRO_OPTS"] = "moe_ep"
    with use_mesh(mesh):
        l_ep = jax.jit(loss)(params, x)
        g_ep = jax.jit(jax.grad(loss))(params, x)
    np.testing.assert_allclose(float(l_ref), float(l_ep), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_ep)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=1e-5)
    print("moe_ep parity OK")
    """)


def test_perf_flags_env_parsing(monkeypatch):
    from repro.perf_flags import enabled
    monkeypatch.delenv("REPRO_OPTS", raising=False)
    assert enabled("tri") and enabled("moe_ep")
    monkeypatch.setenv("REPRO_OPTS", "")
    assert not enabled("tri")
    monkeypatch.setenv("REPRO_OPTS", "tri, pushdown")
    assert enabled("tri") and enabled("pushdown") and not enabled("chunkloss")


@pytest.mark.slow
def test_elastic_checkpoint_restore_across_meshes():
    """A checkpoint written under one mesh must restore onto a different mesh
    (elastic scaling): leaves are logical arrays, shardings re-applied."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np, tempfile
    from repro.configs import get_arch
    from repro.distributed import sharding as shd
    from repro.distributed.meshctx import use_mesh
    from repro.train.checkpoint import save_checkpoint, restore_checkpoint

    arch = get_arch("qwen2-1.5b")
    cell = arch.shapes()[0]
    state = arch.init_state(jax.random.PRNGKey(0), cell, reduced=True)
    batch = arch.example_batch(cell, reduced=True)
    step = arch.make_step(cell, reduced=True)

    mesh_a = jax.make_mesh((2, 4), ("data", "model"),
                           axis_types=(jax.sharding.AxisType.Auto,) * 2)
    sh_a = shd.lm_state_shardings(mesh_a, state)
    with use_mesh(mesh_a):
        state_a, _ = jax.jit(step, in_shardings=(sh_a, None))(state, batch)
    root = tempfile.mkdtemp()
    save_checkpoint(root, 1, state_a)

    # restore onto a DIFFERENT mesh shape (8 x 1): elastic scale-out of DP
    mesh_b = jax.make_mesh((8, 1), ("data", "model"),
                           axis_types=(jax.sharding.AxisType.Auto,) * 2)
    sh_b = shd.lm_state_shardings(mesh_b, state)
    restored = restore_checkpoint(root, state_a, shardings=sh_b)
    with use_mesh(mesh_b):
        state_b, metrics = jax.jit(step, in_shardings=(sh_b, None))(restored, batch)
    assert np.isfinite(float(metrics["loss"]))
    # values identical regardless of mesh
    for a, b in zip(jax.tree.leaves(state_a["params"]),
                    jax.tree.leaves(restored["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("elastic restore OK, loss", float(metrics["loss"]))
    """)


@pytest.mark.slow
def test_gnn_bf16_wire_within_tolerance():
    """With the gnnbf16 flag the sharded path ships bf16 feature gathers;
    results must stay within bf16 tolerance of the f32 local path."""
    _run("""
    import os as _os
    _os.environ["REPRO_OPTS"] = "gnnbf16"
    import jax, jax.numpy as jnp, numpy as np
    from repro.models.gnn.common import local_dist, sharded_dist
    from repro.models.gnn import GIN, GINConfig
    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    rng = np.random.default_rng(0)
    N, E = 64, 256
    cfg = GINConfig(d_in=16, n_classes=4, task="node", n_layers=2, d_hidden=16)
    batch = dict(
        x=jnp.asarray(rng.standard_normal((N, 16)), jnp.float32),
        src=jnp.asarray(rng.integers(0, N, E), jnp.int32),
        dst=jnp.asarray(rng.integers(0, N, E), jnp.int32),
        edge_mask=jnp.ones(E, bool), node_mask=jnp.ones(N, bool),
        graph_ids=jnp.zeros(N, jnp.int32), n_graphs=8,
        graph_mask=jnp.ones(8, bool),
        labels=jnp.asarray(rng.integers(0, 4, N), jnp.int32),
        label_mask=jnp.ones(N, bool),
    )
    local = GIN(cfg, local_dist())
    params = local.init(jax.random.PRNGKey(0))
    l_loc = float(jax.jit(local.loss)(params, batch))
    shard = GIN(cfg, sharded_dist(mesh))
    l_sh = float(jax.jit(shard.loss)(params, batch))
    np.testing.assert_allclose(l_loc, l_sh, rtol=2e-2)
    print("gnnbf16 tolerance OK", l_loc, l_sh)
    """)
