"""Chaos suite (DESIGN.md §11): seeded fault injection on the object store,
typed retry/backoff, error-aware hedging, and degrade-to-stale serving.

The core contract under test: with a seeded 5-10% transient + torn + spike
fault schedule on lake-table reads, the full query / batch / lookup /
advance matrix completes with **zero user-visible failures and bit-parity**
against fault-free runs — and the counters prove the faults actually fired.
"""

import threading
import time

import numpy as np
import pytest

from repro.core.engine import GraphLakeEngine
from repro.data.ldbc import generate_ldbc
from repro.errors import (
    LakeCorruptionError,
    MissingObjectError,
    QueryTimeoutError,
    ReproError,
    TransientLakeError,
)
from repro.gsql.session import GraphSession
from repro.lakehouse.faults import FaultInjector, FaultRule, transient_chaos
from repro.lakehouse.io_pool import IOPool
from repro.lakehouse.objectstore import ObjectStore, StoreConfig
from repro.lakehouse.retry import RetryPolicy, default_policy, lake_get
from repro.lakehouse.table import LakeCatalog
from repro.serving.server import QueryServer, ServerConfig


@pytest.fixture
def lake_root(tmp_path):
    root = str(tmp_path / "lake")
    store = ObjectStore(StoreConfig(root=root))
    ldbc = generate_ldbc(store, scale_factor=0.004, n_files=2,
                         row_group_rows=256)
    return root, ldbc


def _chaos_store(root, rate=0.08, seed=7):
    """A second handle on the same lake bytes, reads faulted on tables/."""
    return ObjectStore(StoreConfig(
        root=root, faults=transient_chaos(rate, seed=seed)))


def _session(store, schema):
    eng = GraphLakeEngine(store, schema, materialize_topology=False)
    eng.startup()
    return GraphSession(eng)


def _assert_parity(a, b):
    np.testing.assert_array_equal(a.vset.ids(), b.vset.ids())
    assert a.n_edges_scanned == b.n_edges_scanned
    assert set(a.accumulators) == set(b.accumulators)
    for k in a.accumulators:
        np.testing.assert_array_equal(a.accumulators[k], b.accumulators[k])


# ---------------------------------------------------------------------------
# the injector itself: determinism, counters, classification
# ---------------------------------------------------------------------------

def _schedule(inj, n=200):
    out = []
    for i in range(n):
        try:
            d = inj.intercept("get", f"tables/t/part-{i % 5}")
            out.append(("ok", d.torn, d.spike_mult))
        except TransientLakeError:
            out.append(("transient", False, 1.0))
        except MissingObjectError:
            out.append(("missing", False, 1.0))
    return out


def test_injector_deterministic_per_seed():
    rules = [FaultRule(prefix="tables/", transient_rate=0.1, torn_rate=0.05,
                       spike_rate=0.1, missing_rate=0.02)]
    a = _schedule(FaultInjector(rules, seed=42))
    b = _schedule(FaultInjector(rules, seed=42))
    c = _schedule(FaultInjector(rules, seed=43))
    assert a == b
    assert a != c  # different seed, different schedule
    inj = FaultInjector(rules, seed=42)
    _schedule(inj)
    snap = inj.snapshot()
    assert snap["ops_seen"] == 200
    # one fault max per op: classes partition the fired count
    assert inj.fired() == sum(snap[c] for c in
                              ("transient", "spike", "torn", "missing"))
    assert inj.fired() > 0


def test_injector_prefix_scoping_and_cap():
    inj = FaultInjector([FaultRule(prefix="tables/", transient_rate=1.0,
                                   max_faults=3)], seed=0)
    # off-prefix keys never fault
    for _ in range(10):
        inj.intercept("get", "topology/MANIFEST.json")
    # on-prefix faults stop at the cap
    fired = 0
    for _ in range(10):
        try:
            inj.intercept("get", "tables/t/x")
        except TransientLakeError:
            fired += 1
    assert fired == 3 == inj.fired("transient")


def test_error_taxonomy_bases():
    t = TransientLakeError("x", key="k")
    m = MissingObjectError("x", key="k")
    c = LakeCorruptionError("x", key="k")
    assert isinstance(t, ConnectionError) and isinstance(t, ReproError)
    assert isinstance(m, FileNotFoundError) and isinstance(m, ReproError)
    assert isinstance(c, ValueError) and isinstance(c, ReproError)
    assert "[key=k]" in str(t)


def test_store_maps_raw_filenotfound(tmp_path):
    store = ObjectStore(StoreConfig(root=str(tmp_path / "s")))
    with pytest.raises(MissingObjectError) as ei:
        store.get("tables/nope")
    assert isinstance(ei.value, FileNotFoundError)
    assert ei.value.key == "tables/nope"
    with pytest.raises(MissingObjectError):
        store.size("tables/nope")


# ---------------------------------------------------------------------------
# retry policy: budget, jitter trace, deadline, fatal fail-fast
# ---------------------------------------------------------------------------

def test_retry_budget_exhaustion_carries_trace():
    pol = RetryPolicy(max_attempts=4, base_s=0.0001, cap_s=0.0002)
    calls = []

    def always_fails():
        calls.append(1)
        raise TransientLakeError("throttled", key="tables/k")

    with pytest.raises(TransientLakeError) as ei:
        pol.call(always_fails, key="tables/k")
    assert len(calls) == 4
    assert len(ei.value.attempt_trace) == 4
    assert "retry budget exhausted" in str(ei.value)
    s = pol.snapshot()
    assert s["giveups"] == 1 and s["retries"] == 3 and s["attempts"] == 4


def test_retry_heals_transient():
    pol = RetryPolicy(max_attempts=5, base_s=0.0001, cap_s=0.0002)
    state = {"n": 0}

    def flaky():
        state["n"] += 1
        if state["n"] < 3:
            raise TransientLakeError("reset")
        return b"payload"

    assert pol.call(flaky) == b"payload"
    assert pol.snapshot()["retries"] == 2


def test_retry_fatal_fails_fast_with_trace():
    pol = RetryPolicy(max_attempts=5, base_s=0.0001, cap_s=0.0002)
    state = {"n": 0}

    def transient_then_fatal():
        state["n"] += 1
        if state["n"] == 1:
            raise TransientLakeError("reset")
        raise MissingObjectError("gone", key="tables/k")

    with pytest.raises(MissingObjectError) as ei:
        pol.call(transient_then_fatal, key="tables/k")
    assert state["n"] == 2  # no retries after the fatal
    # the fatal error records the transient attempt that preceded it
    assert len(ei.value.attempt_trace) == 2
    assert pol.snapshot()["fatal"] == 1


def test_retry_deadline_composes_to_timeout():
    pol = RetryPolicy(max_attempts=50, base_s=0.005, cap_s=0.01)

    def always_fails():
        raise TransientLakeError("throttled")

    t0 = time.monotonic()
    with pytest.raises(QueryTimeoutError):
        pol.call(always_fails, deadline=time.monotonic() + 0.03)
    assert time.monotonic() - t0 < 1.0  # gave up at the deadline, not at 50
    assert pol.snapshot()["deadline_aborts"] == 1


def test_lake_get_short_read_is_transient(tmp_path):
    store = ObjectStore(StoreConfig(root=str(tmp_path / "s")))
    store.put("tables/t/a", b"0123456789")
    state = {"n": 0}
    real_get = store.get

    def torn_once(key, offset=0, length=None):
        state["n"] += 1
        data = real_get(key, offset=offset, length=length)
        return data[:-3] if state["n"] == 1 else data

    store.get = torn_once
    pol = RetryPolicy(max_attempts=3, base_s=0.0001, cap_s=0.0002)
    assert lake_get(store, "tables/t/a", length=10, policy=pol) == b"0123456789"
    assert pol.snapshot()["retries"] == 1


# ---------------------------------------------------------------------------
# corruption: durable bad bytes are fatal, typed, not retried forever
# ---------------------------------------------------------------------------

def test_corrupt_magic_is_fatal(tmp_path):
    from repro.lakehouse.columnfile import read_footer, write_column_file

    store = ObjectStore(StoreConfig(root=str(tmp_path / "s")))
    key = "tables/t/f.col"
    write_column_file(store, key, {"c": np.arange(64, dtype=np.int64)})
    blob = store.get(key)
    store.put(key, blob[:-4] + b"XXXX")  # stomp the magic, length intact
    with pytest.raises(LakeCorruptionError) as ei:
        read_footer(store, key)
    assert ei.value.key == key


def test_corrupt_footer_is_fatal(tmp_path):
    from repro.lakehouse.columnfile import read_footer, write_column_file
    import struct

    store = ObjectStore(StoreConfig(root=str(tmp_path / "s")))
    key = "tables/t/f.col"
    write_column_file(store, key, {"c": np.arange(64, dtype=np.int64)})
    garbage = b"\xff" * 32
    store.put(key, garbage + struct.pack("<I", len(garbage)) + b"RPF1")
    with pytest.raises(LakeCorruptionError):
        read_footer(store, key)


# ---------------------------------------------------------------------------
# hedged reads: failed primary promotes the backup immediately
# ---------------------------------------------------------------------------

def test_hedge_promotes_backup_on_failed_primary():
    """ISSUE 8 satellite: a primary failing *before* ``backup_after_s``
    must not be returned as the winner — the backup launches immediately
    and its success is the result (no 10 s wait, no leaked exception)."""
    state = {"n": 0}
    lock = threading.Lock()

    def fail_once():
        with lock:
            state["n"] += 1
            first = state["n"] == 1
        if first:
            raise TransientLakeError("primary throttled")
        return b"ok"

    with IOPool(n_threads=4) as pool:
        t0 = time.monotonic()
        out = pool.fetch_with_backup(fail_once, backup_after_s=10.0)
        dt = time.monotonic() - t0
    assert out == b"ok"
    assert dt < 5.0  # did not wait out the straggler deadline
    assert pool.stats["hedged_errors"] == 1
    assert pool.stats["backup_fetches"] == 1
    assert pool.stats["backup_wins"] == 1


def test_hedge_both_fail_raises_primary_error():
    def always_fails():
        raise TransientLakeError("down")

    with IOPool(n_threads=4) as pool:
        with pytest.raises(TransientLakeError):
            pool.fetch_with_backup(always_fails, backup_after_s=0.01)
    assert pool.stats["backup_fetches"] == 1


def test_hedge_slow_primary_still_wins_backup():
    """The original straggler path: primary sleeps past the deadline, the
    backup (fast) wins; the abandoned primary's result is consumed."""
    state = {"n": 0}
    lock = threading.Lock()

    def slow_then_fast():
        with lock:
            state["n"] += 1
            first = state["n"] == 1
        if first:
            time.sleep(0.3)
        return b"v"

    with IOPool(n_threads=4) as pool:
        t0 = time.monotonic()
        assert pool.fetch_with_backup(slow_then_fast, backup_after_s=0.02) == b"v"
        assert time.monotonic() - t0 < 0.3
    assert pool.stats["backup_wins"] == 1


# ---------------------------------------------------------------------------
# the matrix: query / batch / lookup / advance under seeded chaos,
# bit-parity with the fault-free run
# ---------------------------------------------------------------------------

QUERIES = {
    "by_id": "SELECT p FROM Person:p WHERE p.id == $pid",            # lookup
    "fan": ("SELECT p FROM Person:p <-(HasCreator:e)- Comment:c "
            "WHERE p.id == $pid ACCUM p.@deg += 1"),                 # lookup
    "scan": ("SELECT c FROM Tag:t -(HasTag:e)- Comment:c "
             "WHERE t.name == $tag"),                                # full
}


def _install_all(session):
    for name, text in QUERIES.items():
        session.install(name, text)


def test_matrix_bit_parity_under_chaos(lake_root):
    root, ldbc = lake_root
    clean = _session(ObjectStore(StoreConfig(root=root)), ldbc.schema)
    chaos_store = _chaos_store(root, rate=0.08, seed=7)
    retries_before = default_policy().snapshot()["retries"]
    chaos = _session(chaos_store, ldbc.schema)
    try:
        _install_all(clean)
        _install_all(chaos)
        pid = int(clean.engine.topology.idm.raw_ids("Person")[0])

        # solo queries (scan hits a real tag so parity is on a non-empty set)
        assert clean.query("scan", tag="Music").vset.size() > 0
        for name, params in [("by_id", {"pid": pid}), ("fan", {"pid": pid}),
                             ("scan", {"tag": "Music"})]:
            _assert_parity(chaos.query(name, **params),
                           clean.query(name, **params))
        # shared-scan batch
        batch_params = [{"tag": t}
                        for t in ("Music", "Sports", "Politics", "Movies")]
        for a, b in zip(chaos.query_batch("scan", batch_params),
                        clean.query_batch("scan", batch_params)):
            _assert_parity(a, b)
        # point-lookup fast path
        _assert_parity(chaos.lookup("by_id", pid=pid),
                       clean.lookup("by_id", pid=pid))
        _assert_parity(chaos.lookup("fan", pid=pid),
                       clean.lookup("fan", pid=pid))

        # advance: commit new rows through the clean handle, advance both
        new_cids = (np.arange(20, dtype=np.int64) + ldbc.n_comments + 1) * 10 + 3
        lake = LakeCatalog(ObjectStore(StoreConfig(root=root)))
        person_raw = clean.engine.topology.idm.raw_ids("Person")
        lake.table("Comment").append_files([{
            "id": new_cids,
            "creationDate": np.full(20, 20230601, dtype=np.int64),
            "length": np.arange(20, dtype=np.int64) + 1,
            "browserUsed": np.array(["Chrome"] * 20, dtype=object),
        }])
        lake.table("Comment_HasCreator_Person").append_files([{
            "src": new_cids,
            "dst": person_raw[np.arange(20) % len(person_raw)],
            "creationDate": np.full(20, 20230601, dtype=np.int64),
        }])
        assert clean.engine.advance().changed
        assert chaos.engine.advance().changed  # advance survives the faults
        _assert_parity(chaos.query("fan", pid=pid),
                       clean.query("fan", pid=pid))

        # the schedule actually exercised the engine: faults fired, retries
        # healed them, and none of it surfaced
        assert chaos_store.faults.fired() > 0, chaos_store.faults.snapshot()
        assert default_policy().snapshot()["retries"] > retries_before
    finally:
        clean.engine.close()
        chaos.engine.close()


def test_missing_fault_surfaces_typed(lake_root):
    """Fatal faults are NOT retried into oblivion: a missing-key fault
    surfaces as the typed MissingObjectError immediately."""
    root, ldbc = lake_root
    store = ObjectStore(StoreConfig(
        root=root,
        faults=FaultInjector([FaultRule(prefix="tables/", missing_rate=1.0)],
                             seed=0)))
    with pytest.raises(MissingObjectError) as ei:
        _session(store, ldbc.schema)
    assert isinstance(ei.value, FileNotFoundError)
    assert isinstance(ei.value, ReproError)
    assert store.faults.fired("missing") == 1  # first touch, no retries


# ---------------------------------------------------------------------------
# degrade-to-stale serving: breaker opens, stale epoch served honestly,
# half-open probe closes it
# ---------------------------------------------------------------------------

def _wait_until(cond, timeout_s=10.0):
    deadline = time.monotonic() + timeout_s
    while time.monotonic() < deadline:
        if cond():
            return True
        time.sleep(0.005)
    return False


def test_breaker_opens_serves_degraded_then_recovers(lake_root):
    root, ldbc = lake_root
    session = _session(ObjectStore(StoreConfig(root=root)), ldbc.schema)
    _install_all(session)
    engine = session.engine
    pid = int(engine.topology.idm.raw_ids("Person")[0])
    real_advance = engine.advance
    fail = {"on": True}

    def flaky_advance():
        if fail["on"]:
            raise TransientLakeError("lake unreachable", key="tables/...")
        return real_advance()

    engine.advance = flaky_advance
    server = QueryServer(session, config=ServerConfig(
        n_workers=1, refresh_interval_s=0.01,
        breaker_threshold=2, breaker_cooldown_s=0.05))
    try:
        # consecutive failures open the breaker
        assert _wait_until(lambda: server.health()["breaker"] == "open")
        h = server.health()
        assert h["refresh"]["consecutive_failures"] >= 2
        assert "TransientLakeError" in h["refresh"]["last_error"]
        assert h["refresh"]["breaker_opens"] == 1

        # open breaker: results still correct, stamped degraded, honest
        # staleness from the last good pinned epoch
        rid = server.submit("by_id", pid=pid)
        res = server.result(rid)
        assert res.ok and res.degraded
        assert res.value.degraded
        assert res.value.epoch_id == engine.current_epoch().epoch_id
        assert res.value.staleness_s >= 0.0

        # the lookup fast path carries the stamp too
        rid = server.submit("fan", pid=pid)
        res = server.result(rid)
        assert res.ok and res.degraded and res.value.degraded

        # lake heals: the half-open probe closes the breaker
        fail["on"] = False
        assert _wait_until(lambda: server.health()["breaker"] == "closed")
        h = server.health()
        assert h["refresh"]["half_open_probes"] >= 1
        assert h["refresh"]["breaker_closes"] >= 1
        assert h["refresh"]["consecutive_failures"] == 0
        rid = server.submit("by_id", pid=pid)
        res = server.result(rid)
        assert res.ok and not res.degraded and not res.value.degraded
    finally:
        engine.advance = real_advance
        server.close()
        engine.close()


def test_health_snapshot_shape(lake_root):
    root, ldbc = lake_root
    session = _session(ObjectStore(StoreConfig(root=root)), ldbc.schema)
    server = QueryServer(session, config=ServerConfig(
        n_workers=1, refresh_interval_s=0.0))  # refresher off
    try:
        h = server.health()
        assert h["breaker"] == "closed"
        for key in ("refresh", "stats", "queue_depth", "retry",
                    "epoch_id", "staleness_s", "io_pool"):
            assert key in h, key
        assert "last_error" in h["refresh"]
        assert "hedged_errors" in h["io_pool"]
        assert "attempts" in h["retry"]
    finally:
        server.close()
        session.engine.close()
