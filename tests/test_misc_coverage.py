"""Coverage for the smaller substrate modules: meshctx, elastic mesh,
metrics, synthetic data, query predicates, engine property test vs oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.engine import GraphLakeEngine
from repro.core.query import Query, accum_sum, eq, ge, gt, isin, le, lt, ne
from repro.data.synthetic import molecule_batch
from repro.distributed.meshctx import constrain, current_mesh, use_mesh
from repro.launch.mesh import make_elastic_mesh
from repro.train.metrics import MetricsLogger


# ---------------------------------------------------------------------------
# meshctx
# ---------------------------------------------------------------------------

def test_meshctx_noop_without_mesh():
    x = jnp.ones((4, 4))
    assert current_mesh() is None
    y = constrain(x, "dp", "model")     # no mesh -> identity
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_meshctx_nesting_restores():
    class FakeMesh:  # only identity matters for the context
        axis_names = ("data",)
    m = FakeMesh()
    with use_mesh(m):
        assert current_mesh() is m
        with use_mesh(None):
            assert current_mesh() is None
        assert current_mesh() is m
    assert current_mesh() is None


def test_meshctx_rank_mismatch_raises():
    class FakeMesh:
        axis_names = ("data",)
    with use_mesh(FakeMesh()):
        with pytest.raises(ValueError):
            constrain(jnp.ones((2, 2)), "dp")


# ---------------------------------------------------------------------------
# elastic mesh
# ---------------------------------------------------------------------------

def test_make_elastic_mesh_single_device():
    mesh = make_elastic_mesh()          # 1 CPU device
    assert mesh.devices.size == 1
    assert set(mesh.axis_names) == {"data", "model"}


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def test_metrics_logger(tmp_path):
    path = str(tmp_path / "m.jsonl")
    log = MetricsLogger(path, log_every=2)
    for s in range(6):
        log.log(s, {"loss": 10.0 - s})
    assert log.smoothed("loss", window=3) == pytest.approx(10.0 - 4)
    lines = open(path).read().strip().splitlines()
    assert len(lines) == 3              # steps 0, 2, 4


# ---------------------------------------------------------------------------
# synthetic data
# ---------------------------------------------------------------------------

def test_molecule_batch_block_diagonal():
    b = molecule_batch(n_graphs=4, nodes_per=10, edges_per=12, seed=3)
    assert b["src"].shape == (48,)
    # edges never cross graph boundaries
    for s, d in zip(b["src"], b["dst"]):
        assert s // 10 == d // 10
    assert b["graph_ids"].max() == 3


# ---------------------------------------------------------------------------
# query predicates
# ---------------------------------------------------------------------------

def test_predicate_combinators():
    frame = {"v.a": np.array([1, 5, 9]), "v.b": np.array([2.0, 2.0, 7.0])}
    p = (gt("a", 2) & le("b", 2.0)) | eq("a", 1)
    np.testing.assert_array_equal(p.evaluate(frame, "v"), [True, True, False])
    np.testing.assert_array_equal(ne("a", 5).evaluate(frame, "v"),
                                  [True, False, True])
    np.testing.assert_array_equal(lt("a", 5).evaluate(frame, "v"),
                                  [True, False, False])
    np.testing.assert_array_equal(ge("a", 5).evaluate(frame, "v"),
                                  [False, True, True])
    np.testing.assert_array_equal(isin("a", [1, 9]).evaluate(frame, "v"),
                                  [True, False, True])


# ---------------------------------------------------------------------------
# engine property test vs oracle on random graphs
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(
    st.integers(min_value=10, max_value=60),
    st.integers(min_value=20, max_value=200),
    st.integers(min_value=0, max_value=10 ** 6),
)
def test_engine_aggregation_matches_oracle(n_nodes, n_edges, seed):
    """Random graph + random per-edge weight filter: the engine's EdgeScan
    aggregation equals a numpy group-by oracle."""
    import shutil, tempfile
    from repro.data.graph500 import graph500_schema
    from repro.lakehouse.objectstore import ObjectStore, StoreConfig
    from repro.lakehouse.table import ColumnSpec, TableSchema
    from repro.lakehouse.writer import write_table

    rng = np.random.default_rng(seed)
    root = tempfile.mkdtemp(prefix="prop_lake_")
    store = ObjectStore(StoreConfig(root=root))
    src = rng.integers(0, n_nodes, n_edges)
    dst = rng.integers(0, n_nodes, n_edges)
    w = rng.random(n_edges)
    write_table(store, TableSchema("Node", [ColumnSpec("id", "int64",
                role="primary_key")]), {"id": np.arange(n_nodes)}, n_files=2)
    write_table(store, TableSchema("Node_Edge_Node", [
        ColumnSpec("src", "int64", role="foreign_key"),
        ColumnSpec("dst", "int64", role="foreign_key"),
        ColumnSpec("weight", "float64"),
    ]), {"src": src, "dst": dst, "weight": w}, n_files=2)

    with GraphLakeEngine(store, graph500_schema(),
                         materialize_topology=False) as eng:
        eng.startup()
        res = (
            Query(eng)
            .vertices("Node")
            .hop("Edge", direction="out",
                 edge_where=gt("weight", 0.5),
                 accum=accum_sum("wsum", "e.weight"))
            .run()
        )
        got = res.accumulators["wsum"][:n_nodes]

    # oracle: raw id == dense id because files are registered in id order
    want = np.zeros(n_nodes)
    np.add.at(want, dst[w > 0.5], w[w > 0.5])
    shutil.rmtree(root, ignore_errors=True)
    np.testing.assert_allclose(got, want, rtol=1e-9)
