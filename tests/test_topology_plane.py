"""Tests: the topology plane — edge-list vs CSR scan parity across
selectivities and directions, adaptive dispatch, CSR lake materialization
round-trip, incremental invalidation, and the offset-range segment kernel."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.csr import CSRIndex
from repro.core.engine import GraphLakeEngine
from repro.core.topology_plane import DEFAULT_CSR_THRESHOLD
from repro.core.types import VSet
from repro.data.graph500 import generate_graph500, graph500_schema
from repro.data.ldbc import generate_ldbc, ldbc_graph_schema
from repro.kernels import ops as kops, ref
from repro.kernels.csr_expand import csr_segment_sum_pallas
from repro.lakehouse.objectstore import ObjectStore, StoreConfig
from repro.lakehouse.table import LakeCatalog


@pytest.fixture(scope="module")
def g500(tmp_path_factory):
    root = tmp_path_factory.mktemp("lake_plane")
    store = ObjectStore(StoreConfig(root=str(root)))
    schema = generate_graph500(store, scale=8, edge_factor=8, n_files=3,
                               row_group_rows=1024)
    eng = GraphLakeEngine(store, schema)
    eng.startup()
    yield eng
    eng.close()


@pytest.fixture
def ldbc_engine(tmp_path):
    store = ObjectStore(StoreConfig(root=str(tmp_path / "lake")))
    generate_ldbc(store, scale_factor=0.01, n_files=3, row_group_rows=256)
    eng = GraphLakeEngine(store, ldbc_graph_schema(), materialize_topology=False)
    eng.startup()
    yield eng
    eng.close()


def _frontier(n, sel, seed=0):
    rng = np.random.default_rng(seed)
    k = max(1, int(n * sel))
    return VSet.from_dense_ids("Node", n, rng.choice(n, size=k, replace=False))


def _assert_frames_identical(a, b):
    np.testing.assert_array_equal(a.u, b.u)
    np.testing.assert_array_equal(a.v, b.v)
    assert a.columns.keys() == b.columns.keys()
    for k in a.columns:
        np.testing.assert_array_equal(a.columns[k], b.columns[k])


# ---------------------------------------------------------------------------
# edge-list vs CSR scan parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sel", [0.0005, 0.01, 0.2, 1.0])
@pytest.mark.parametrize("direction", ["out", "in"])
def test_edge_scan_parity_across_selectivities(g500, sel, direction):
    n = g500.topology.n_vertices("Node")
    frontier = _frontier(n, sel, seed=int(sel * 10_000))
    el = g500.edge_scan(frontier, "Edge", direction,
                        edge_columns=["weight"], strategy="edgelist")
    cs = g500.edge_scan(frontier, "Edge", direction,
                        edge_columns=["weight"], strategy="csr")
    _assert_frames_identical(el, cs)
    if sel >= 0.01:
        assert len(el) > 0  # scans actually matched something


def test_edge_scan_parity_heterogeneous_types(ldbc_engine):
    """Cross-type edge scan (Comment -HasCreator-> Person), both directions."""
    eng = ldbc_engine
    for direction, vt in (("out", "Comment"), ("in", "Person")):
        n = eng.topology.n_vertices(vt)
        ids = np.arange(0, n, 7, dtype=np.int64)
        frontier = VSet.from_dense_ids(vt, n, ids)
        el = eng.edge_scan(frontier, "HasCreator", direction,
                           edge_columns=["creationDate"], strategy="edgelist")
        cs = eng.edge_scan(frontier, "HasCreator", direction,
                           edge_columns=["creationDate"], strategy="csr")
        _assert_frames_identical(el, cs)
        assert len(el) > 0


def test_edge_scan_parity_with_filter(g500):
    n = g500.topology.n_vertices("Node")
    frontier = _frontier(n, 0.05, seed=3)
    flt = lambda f: f["e.weight"] > 0.5
    el = g500.edge_scan(frontier, "Edge", edge_columns=["weight"],
                        edge_filter=flt, strategy="edgelist")
    cs = g500.edge_scan(frontier, "Edge", edge_columns=["weight"],
                        edge_filter=flt, strategy="csr")
    _assert_frames_identical(el, cs)


def test_edge_scan_empty_frontier(g500):
    n = g500.topology.n_vertices("Node")
    empty = VSet.empty("Node", n)
    for strategy in ("edgelist", "csr", "auto"):
        frame = g500.edge_scan(empty, "Edge", strategy=strategy)
        assert len(frame) == 0


# ---------------------------------------------------------------------------
# CSRIndex structure + serialization
# ---------------------------------------------------------------------------

def test_csr_index_matches_numpy_oracle(g500):
    src, dst = g500.concat_edges("Edge")
    csr = g500.plane.csr("Edge")
    n = g500.topology.n_vertices("Node")
    np.testing.assert_array_equal(csr.degrees("out"), np.bincount(src, minlength=n))
    np.testing.assert_array_equal(csr.degrees("in"), np.bincount(dst, minlength=n))
    v = int(src[0])
    np.testing.assert_array_equal(np.sort(csr.neighbors(v, "out")),
                                  np.sort(dst[src == v]))
    # dst-sorted view is a permutation of the edge set with sorted dst
    s2, d2, eid = csr.edges_by_dst()
    assert np.all(np.diff(d2) >= 0)
    np.testing.assert_array_equal(s2, src[eid])
    np.testing.assert_array_equal(d2, dst[eid])


def test_csr_bytes_roundtrip(g500):
    csr = g500.plane.csr("Edge")
    back = CSRIndex.from_bytes(csr.to_bytes())
    assert back.edge_type == csr.edge_type
    assert (back.n_src, back.n_dst) == (csr.n_src, csr.n_dst)
    for name in ("fwd_indptr", "fwd_dst", "fwd_eid",
                 "rev_indptr", "rev_src", "rev_eid"):
        np.testing.assert_array_equal(getattr(back, name), getattr(csr, name))


def test_csr_survives_second_connection(tmp_path):
    """Materialized topology restores the CSR index — no rebuild."""
    store = ObjectStore(StoreConfig(root=str(tmp_path / "lake")))
    schema = generate_graph500(store, scale=7, edge_factor=8, n_files=2,
                               row_group_rows=1024)
    with GraphLakeEngine(store, schema) as eng1:
        eng1.startup()           # first connection: builds + materializes CSR
        assert eng1.startup_mode == "first_connection"
        assert eng1.plane.csr_ready("Edge")
        csr1 = eng1.plane.csr("Edge")
        n = eng1.topology.n_vertices("Node")
        frontier = _frontier(n, 0.01)
        frame1 = eng1.edge_scan(frontier, "Edge", strategy="csr")

    with GraphLakeEngine(store, schema) as eng2:
        eng2.startup()
        assert eng2.startup_mode == "second_connection"
        assert eng2.plane.csr_ready("Edge")  # restored, not rebuilt
        csr2 = eng2.plane.csr("Edge")
        np.testing.assert_array_equal(csr1.fwd_indptr, csr2.fwd_indptr)
        np.testing.assert_array_equal(csr1.rev_src, csr2.rev_src)
        frame2 = eng2.edge_scan(frontier, "Edge", strategy="csr")
        _assert_frames_identical(frame1, frame2)


# ---------------------------------------------------------------------------
# adaptive dispatch
# ---------------------------------------------------------------------------

def test_adaptive_dispatch_by_selectivity(g500):
    n = g500.topology.n_vertices("Node")
    g500.edge_scan(_frontier(n, 0.001), "Edge", strategy="auto")
    assert g500.plane.last_strategy["Edge"] == "csr"
    g500.edge_scan(g500.all_vertices("Node"), "Edge", strategy="auto")
    assert g500.plane.last_strategy["Edge"] == "edgelist"


def test_adaptive_threshold_override(g500, monkeypatch):
    n = g500.topology.n_vertices("Node")
    small = _frontier(n, 0.001)
    # threshold 0 -> nothing is "low selectivity" -> edge lists always
    monkeypatch.setenv("REPRO_OPTS", "csr=0.0")
    g500.edge_scan(small, "Edge", strategy="auto")
    assert g500.plane.last_strategy["Edge"] == "edgelist"
    # threshold 1.0 -> every frontier qualifies for CSR
    monkeypatch.setenv("REPRO_OPTS", "csr=1.0")
    g500.edge_scan(g500.all_vertices("Node"), "Edge", strategy="auto")
    assert g500.plane.last_strategy["Edge"] == "csr"
    assert g500.plane.threshold() == 1.0


def test_csr_flag_disables_dispatch(g500, monkeypatch):
    n = g500.topology.n_vertices("Node")
    monkeypatch.setenv("REPRO_OPTS", "")  # baseline: all perf flags off
    g500.edge_scan(_frontier(n, 0.001), "Edge", strategy="auto")
    assert g500.plane.last_strategy["Edge"] == "edgelist"
    assert g500.plane.threshold() == DEFAULT_CSR_THRESHOLD


# ---------------------------------------------------------------------------
# invalidation on incremental refresh
# ---------------------------------------------------------------------------

def test_refresh_invalidates_plane(ldbc_engine):
    eng = ldbc_engine
    topo = eng.topology
    before_edges = topo.n_edges("Knows")
    eng.plane.csr("Knows")
    src0, _ = eng.concat_edges("Knows")
    assert eng.plane.csr_ready("Knows")

    lake = LakeCatalog(eng.store)
    t = lake.table("Person_Knows_Person")
    person_raw = topo.idm.raw_ids("Person")
    t.append_files([{
        "src": person_raw[:10],
        "dst": person_raw[10:20],
        "creationDate": np.full(10, 20230101, dtype=np.int64),
    }])
    added, removed = topo.refresh_edges(eng.store, lake, "Knows")
    assert (added, removed) == (1, 0)
    assert not eng.plane.csr_ready("Knows")      # CSR dropped
    src1, _ = eng.concat_edges("Knows")          # concat cache rebuilt
    assert len(src1) == len(src0) + 10
    assert eng.plane.csr("Knows").n_edges == before_edges + 10

    # parity still holds on the refreshed topology
    n = topo.n_vertices("Person")
    frontier = VSet.from_dense_ids("Person", n, np.arange(0, n, 3))
    el = eng.edge_scan(frontier, "Knows", strategy="edgelist")
    cs = eng.edge_scan(frontier, "Knows", strategy="csr")
    _assert_frames_identical(el, cs)


def test_concat_edges_cached_until_invalidated(g500):
    a = g500.concat_edges("Edge")
    b = g500.concat_edges("Edge")
    assert a[0] is b[0] and a[1] is b[1]


# ---------------------------------------------------------------------------
# offset-range segment kernel (CSR frontier-expand path)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("e,n,d", [(64, 16, 8), (1000, 100, 16), (4096, 512, 128),
                                   (100, 1000, 4), (1, 1, 8)])
def test_csr_segment_sum_kernel_matches_ref(e, n, d):
    rng = np.random.default_rng(e + n + d)
    dst = np.sort(rng.integers(0, n, size=e))
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(np.bincount(dst, minlength=n), out=indptr[1:])
    values = jnp.asarray(rng.standard_normal((e, d)), dtype=jnp.float32)
    got = csr_segment_sum_pallas(values, jnp.asarray(indptr), n, interpret=True)
    want = ref.csr_segment_sum(values, jnp.asarray(indptr), n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_csr_segment_sum_matches_edge_segment_sum(g500):
    """The CSR offset-range reduction equals the scattered-id reduction."""
    csr = g500.plane.csr("Edge")
    n = g500.topology.n_vertices("Node")
    src, dst = g500.edges_by_dst("Edge")
    rng = np.random.default_rng(0)
    vals = jnp.asarray(rng.standard_normal((len(src), 4)), dtype=jnp.float32)
    a = kops.csr_segment_sum(vals, jnp.asarray(csr.rev_indptr), n)
    b = ref.edge_segment_sum(vals, jnp.asarray(dst, dtype=jnp.int32), n)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-4)


def test_csr_segment_sum_1d(g500):
    csr = g500.plane.csr("Edge")
    n = g500.topology.n_vertices("Node")
    vals = jnp.ones(csr.n_edges, dtype=jnp.float32)
    got = kops.csr_segment_sum(vals, jnp.asarray(csr.rev_indptr), n)
    np.testing.assert_allclose(np.asarray(got), csr.degrees("in").astype(np.float32))
