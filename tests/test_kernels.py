"""Per-kernel allclose tests: Pallas (interpret=True) vs pure-jnp oracle,
swept over shapes and dtypes, plus hypothesis property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.edge_scan import edge_segment_sum_pallas
from repro.kernels.embedding_bag import embedding_bag_pallas
from repro.kernels.flash_attention import flash_attention_pallas


def _rng(seed=0):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# edge_scan kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("e,n,d", [(64, 16, 8), (1000, 100, 16), (4096, 512, 128),
                                   (100, 1000, 4), (1, 1, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_edge_segment_sum_shapes(e, n, d, dtype):
    rng = _rng(e + n + d)
    values = jnp.asarray(rng.standard_normal((e, d)), dtype=dtype)
    dst = jnp.asarray(rng.integers(0, n, size=e), dtype=jnp.int32)
    got = edge_segment_sum_pallas(values, dst, n, block_e=128, block_n=64,
                                  interpret=True)
    want = ref.edge_segment_sum(values.astype(jnp.float32), dst, n)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, dtype=np.float32), np.asarray(want),
                               rtol=tol, atol=tol)


def test_edge_segment_sum_sorted_input():
    """Sorted dst (the paper's sorted-FK layout) must give exact results too."""
    rng = _rng(5)
    e, n, d = 2048, 256, 32
    dst = jnp.asarray(np.sort(rng.integers(0, n, size=e)), dtype=jnp.int32)
    values = jnp.asarray(rng.standard_normal((e, d)), dtype=jnp.float32)
    got = edge_segment_sum_pallas(values, dst, n, block_e=256, block_n=64,
                                  interpret=True)
    want = ref.edge_segment_sum(values, dst, n)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    st.integers(min_value=1, max_value=300),
    st.integers(min_value=1, max_value=50),
    st.integers(min_value=1, max_value=16),
)
def test_edge_segment_sum_property(e, n, d):
    rng = _rng(e * 31 + n * 7 + d)
    values = jnp.asarray(rng.standard_normal((e, d)), dtype=jnp.float32)
    dst = jnp.asarray(rng.integers(0, n, size=e), dtype=jnp.int32)
    got = edge_segment_sum_pallas(values, dst, n, block_e=64, block_n=32,
                                  interpret=True)
    want = ref.edge_segment_sum(values, dst, n)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    # conservation: total mass preserved
    np.testing.assert_allclose(np.asarray(got).sum(), np.asarray(values).sum(),
                               rtol=1e-3, atol=1e-3)


def test_masked_edge_segment_sum_frontier_semantics():
    rng = _rng(9)
    e, n, d = 512, 64, 8
    src = jnp.asarray(rng.integers(0, n, size=e), dtype=jnp.int32)
    dst = jnp.asarray(rng.integers(0, n, size=e), dtype=jnp.int32)
    values = jnp.asarray(rng.standard_normal((e, d)), dtype=jnp.float32)
    frontier = jnp.asarray(rng.random(n) < 0.3)
    got = ref.masked_edge_segment_sum(values, src, dst, frontier, n)
    mask = np.asarray(frontier)[np.asarray(src)]
    want = ref.edge_segment_sum(values * mask[:, None].astype(np.float32), dst, n)
    np.testing.assert_allclose(got, want, rtol=1e-6)


# ---------------------------------------------------------------------------
# embedding_bag kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("v,d,b,l", [(100, 8, 32, 4), (1000, 16, 64, 8),
                                     (512, 128, 256, 2), (50, 10, 7, 39)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_embedding_bag_shapes(v, d, b, l, dtype):
    rng = _rng(v + d + b + l)
    table = jnp.asarray(rng.standard_normal((v, d)), dtype=dtype)
    idx = jnp.asarray(rng.integers(0, v, size=(b, l)), dtype=jnp.int32)
    w = jnp.asarray((rng.random((b, l)) < 0.8).astype(np.float32))
    got = embedding_bag_pallas(table, idx, w, block_b=64, block_v=128,
                               interpret=True)
    want = ref.embedding_bag(table.astype(jnp.float32), idx, w)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, dtype=np.float32), np.asarray(want),
                               rtol=tol, atol=tol)


@settings(max_examples=15, deadline=None)
@given(
    st.integers(min_value=2, max_value=200),
    st.integers(min_value=1, max_value=12),
    st.integers(min_value=1, max_value=40),
)
def test_embedding_bag_property(v, l, b):
    rng = _rng(v * 13 + l * 5 + b)
    d = 8
    table = jnp.asarray(rng.standard_normal((v, d)), dtype=jnp.float32)
    idx = jnp.asarray(rng.integers(0, v, size=(b, l)), dtype=jnp.int32)
    w = jnp.asarray(rng.random((b, l)).astype(np.float32))
    got = embedding_bag_pallas(table, idx, w, block_b=32, block_v=64, interpret=True)
    want = ref.embedding_bag(table, idx, w)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_embedding_bag_padding_weights_zero():
    """Weight-0 (padding) entries must not contribute even with index -1."""
    table = jnp.asarray(np.eye(8, dtype=np.float32))
    idx = jnp.asarray([[0, -1], [3, -1]], dtype=jnp.int32)
    w = jnp.asarray([[1.0, 0.0], [2.0, 0.0]], dtype=jnp.float32)
    got = embedding_bag_pallas(table, idx, w, block_b=8, block_v=8, interpret=True)
    want = np.zeros((2, 8), dtype=np.float32)
    want[0, 0] = 1.0
    want[1, 3] = 2.0
    np.testing.assert_allclose(got, want, atol=1e-6)


# ---------------------------------------------------------------------------
# flash attention kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,h,s,dh", [(1, 2, 128, 32), (2, 4, 256, 64), (1, 1, 64, 128)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_vs_naive(b, h, s, dh, causal):
    rng = _rng(b * h + s + dh)
    q = jnp.asarray(rng.standard_normal((b, h, s, dh)), dtype=jnp.float32) * 0.3
    k = jnp.asarray(rng.standard_normal((b, h, s, dh)), dtype=jnp.float32) * 0.3
    v = jnp.asarray(rng.standard_normal((b, h, s, dh)), dtype=jnp.float32)
    got = flash_attention_pallas(q, k, v, causal=causal, block_q=64, block_kv=64,
                                 interpret=True)
    want = ref.attention(q, k, v, causal=causal)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_flash_attention_decode_alignment():
    """q shorter than kv (decode/chunked prefill): causal offset aligns to the
    kv tail."""
    rng = _rng(77)
    b, h, dh = 1, 2, 32
    q = jnp.asarray(rng.standard_normal((b, h, 64, dh)), dtype=jnp.float32) * 0.3
    k = jnp.asarray(rng.standard_normal((b, h, 256, dh)), dtype=jnp.float32) * 0.3
    v = jnp.asarray(rng.standard_normal((b, h, 256, dh)), dtype=jnp.float32)
    got = flash_attention_pallas(q, k, v, causal=True, block_q=64, block_kv=64,
                                 interpret=True)
    want = ref.attention(q, k, v, causal=True)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_blockwise_ref_matches_naive():
    """The CPU dry-run attention path must match the naive oracle too."""
    rng = _rng(11)
    for (b, h, s, dh, causal) in [(2, 2, 96, 32, True), (1, 4, 200, 64, False)]:
        q = jnp.asarray(rng.standard_normal((b, h, s, dh)), dtype=jnp.float32) * 0.4
        k = jnp.asarray(rng.standard_normal((b, h, s, dh)), dtype=jnp.float32) * 0.4
        v = jnp.asarray(rng.standard_normal((b, h, s, dh)), dtype=jnp.float32)
        got = ref.attention_blockwise(q, k, v, causal=causal, block_kv=64)
        want = ref.attention(q, k, v, causal=causal)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_bf16_attention_tolerance():
    rng = _rng(13)
    q = jnp.asarray(rng.standard_normal((1, 2, 128, 64)), dtype=jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((1, 2, 128, 64)), dtype=jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((1, 2, 128, 64)), dtype=jnp.bfloat16)
    got = flash_attention_pallas(q, k, v, causal=True, block_q=64, block_kv=64,
                                 interpret=True)
    want = ref.attention(q.astype(jnp.float32), k.astype(jnp.float32),
                         v.astype(jnp.float32), causal=True)
    np.testing.assert_allclose(np.asarray(got, dtype=np.float32), np.asarray(want),
                               rtol=5e-2, atol=5e-2)


# ---------------------------------------------------------------------------
# ops dispatch
# ---------------------------------------------------------------------------

def test_ops_dispatch_interpret(monkeypatch):
    from repro.kernels import ops

    monkeypatch.setenv("REPRO_PALLAS", "interpret")
    rng = _rng(1)
    values = jnp.asarray(rng.standard_normal((256, 16)), dtype=jnp.float32)
    dst = jnp.asarray(rng.integers(0, 32, size=256), dtype=jnp.int32)
    got = ops.edge_segment_sum(values, dst, 32)
    want = ref.edge_segment_sum(values, dst, 32)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    monkeypatch.setenv("REPRO_PALLAS", "off")
    got2 = ops.edge_segment_sum(values, dst, 32)
    np.testing.assert_allclose(got2, want, rtol=1e-6)


# ---------------------------------------------------------------------------
# stacked segment sum + pytree stacking (shared-scan batch layout)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("r,e,n", [(1, 64, 16), (3, 700, 50), (8, 4096, 512)])
def test_stacked_segment_sum(monkeypatch, r, e, n):
    from repro.kernels import ops

    monkeypatch.setenv("REPRO_PALLAS", "interpret")
    rng = _rng(2)
    vals = jnp.asarray(rng.standard_normal((r, e)), dtype=jnp.float32)
    ids = jnp.asarray(rng.integers(0, n, size=e), dtype=jnp.int32)
    got = np.asarray(ops.stacked_segment_sum(vals, ids, n))
    want = np.stack([
        np.bincount(np.asarray(ids), weights=np.asarray(vals)[i],
                    minlength=n)[:n]
        for i in range(r)
    ]).astype(np.float32)
    assert got.shape == (r, n)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    monkeypatch.setenv("REPRO_PALLAS", "off")
    got2 = np.asarray(ops.stacked_segment_sum(vals, ids, n))
    np.testing.assert_allclose(got2, want, rtol=1e-5, atol=1e-5)


def test_tree_stack_unstack_roundtrip():
    from repro.kernels import ops

    trees = [
        {"frontier": jnp.arange(6, dtype=jnp.float32) * i,
         "acc": (jnp.ones((2, 3)) * i, jnp.zeros((4,)) + i)}
        for i in range(5)
    ]
    stacked = ops.tree_stack(trees)
    assert stacked["frontier"].shape == (5, 6)
    assert stacked["acc"][0].shape == (5, 2, 3)
    back = ops.tree_unstack(stacked)
    assert len(back) == 5
    for orig, got in zip(trees, back):
        assert jax.tree.structure(orig) == jax.tree.structure(got)
        for a, b in zip(jax.tree.leaves(orig), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
