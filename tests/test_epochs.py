"""Tests: snapshot-pinned epochs — torn-read consistency, incremental delta
sync (edge/vertex appends, CSR merge-extension, IDM extension), file-scoped
cache invalidation, refcounted retirement, and the serving refresher."""

import threading
import time

import numpy as np
import pytest

from repro.core.csr import CSRIndex
from repro.core.engine import GraphLakeEngine
from repro.core.query import ExecOptions, Predicate, Query, eq, gt
from repro.data.ldbc import generate_ldbc, ldbc_graph_schema
from repro.lakehouse.objectstore import ObjectStore, StoreConfig
from repro.lakehouse.table import LakeCatalog
from repro.serving.server import QueryServer, ServerConfig


@pytest.fixture
def store(tmp_path):
    return ObjectStore(StoreConfig(root=str(tmp_path / "lake")))


@pytest.fixture
def ldbc(store):
    return generate_ldbc(store, scale_factor=0.004, n_files=2, row_group_rows=256)


@pytest.fixture
def engine(store, ldbc):
    # materialize=False: rebuild/cold-parity tests must always read the
    # *current* lake snapshot, never a stale materialized topology blob
    eng = GraphLakeEngine(store, ldbc.schema, materialize_topology=False)
    eng.startup()
    yield eng
    eng.close()


def _assert_parity(a, b):
    assert a.n_edges_scanned == b.n_edges_scanned
    np.testing.assert_array_equal(a.vset.ids(), b.vset.ids())
    for fa, fb in zip(a.frames, b.frames):
        np.testing.assert_array_equal(fa.u, fb.u)
        np.testing.assert_array_equal(fa.v, fb.v)
        assert set(fa.columns) == set(fb.columns)
        for k in fa.columns:
            np.testing.assert_array_equal(fa.columns[k], fb.columns[k])


def _append_comments_and_edges(store, eng, ldbc, n_new=30, date=20230601):
    """Commit one new Comment vertex file + matching HasCreator edge file."""
    new_cids = np.arange(ldbc.n_comments + 1, ldbc.n_comments + n_new + 1,
                         dtype=np.int64) * 10 + 3
    lake = LakeCatalog(store)
    lake.table("Comment").append_files([{
        "id": new_cids,
        "creationDate": np.full(n_new, date, dtype=np.int64),
        "length": np.arange(n_new, dtype=np.int64) + 1,
        "browserUsed": np.array(["Chrome"] * n_new, dtype=object),
    }])
    person_raw = eng.topology.idm.raw_ids("Person")
    lake.table("Comment_HasCreator_Person").append_files([{
        "src": new_cids,
        "dst": person_raw[np.arange(n_new) % len(person_raw)],
        "creationDate": np.full(n_new, date, dtype=np.int64),
    }])
    return new_cids


# ---------------------------------------------------------------------------
# bootstrap + result stamping
# ---------------------------------------------------------------------------

def test_bootstrap_pins_and_result_stamp(engine):
    epoch = engine.current_epoch()
    assert epoch.epoch_id == 1
    # pins cover every mapped table with a real snapshot + file set
    for pin in list(epoch.vertex_pins.values()) + list(epoch.edge_pins.values()):
        assert pin.snapshot_id >= 1
        assert len(pin.data_files) > 0
    res = Query(engine).vertices("Comment").hop("HasCreator").run()
    assert res.epoch_id == epoch.epoch_id
    assert res.staleness_s >= 0.0
    # nothing changed: advance is a no-op and the epoch stays published
    report = engine.advance()
    assert not report.changed and report.mode == "noop"
    assert engine.current_epoch() is epoch


# ---------------------------------------------------------------------------
# the torn-read regression (the acceptance criterion)
# ---------------------------------------------------------------------------

def test_commit_mid_query_yields_pre_commit_results(store, ldbc, engine):
    """Committing new edge+vertex files *during* a running query must leave
    the result bit-identical to the pre-commit epoch; the next advance()
    makes the new data visible."""
    def build_query():
        return (Query(engine)
                .vertices("Tag", where=eq("name", "Music"))
                .hop("HasTag", direction="in", edge_where=mid_hop_pred)
                .hop("HasCreator", direction="out",
                     edge_where=gt("creationDate", 20100101)))

    # a pass-through predicate for the *reference* run
    mid_hop_pred = Predicate(lambda fr, p: np.ones(len(fr["u"]), dtype=bool), ())
    res_ref = build_query().run(ExecOptions(pushdown=False))

    # now a side-effecting predicate: the first evaluation (mid-query,
    # between hop 1 and hop 2) commits new Comment vertices + HasCreator
    # edges and *publishes a new epoch* via advance()
    fired = {"done": False}

    def commit_midway(frame, prefix):
        if not fired["done"]:
            fired["done"] = True
            _append_comments_and_edges(store, engine, ldbc, n_new=25)
            report = engine.advance()
            assert report.changed and report.mode == "incremental"
        return np.ones(len(frame["u"]), dtype=bool)

    mid_hop_pred = Predicate(commit_midway, ())
    res_torn = build_query().run(ExecOptions(pushdown=False))
    assert fired["done"], "the mid-query commit hook never fired"

    # bit-identical to the pre-commit epoch, and pinned to it
    _assert_parity(res_ref, res_torn)
    assert res_torn.epoch_id == res_ref.epoch_id

    # the *next* run picks up the already-published epoch and sees new data
    mid_hop_pred = Predicate(lambda fr, p: np.ones(len(fr["u"]), dtype=bool), ())
    res_fresh = build_query().run(ExecOptions(pushdown=False))
    assert res_fresh.epoch_id > res_torn.epoch_id
    count = Query(engine).vertices("Comment").hop("HasCreator").run()
    assert count.n_edges_scanned == ldbc.n_comments + 25


# ---------------------------------------------------------------------------
# incremental delta sync
# ---------------------------------------------------------------------------

def test_edge_append_extends_csr_incrementally(store, ldbc, engine):
    e0 = engine.current_epoch()
    csr0 = e0.plane.csr("HasCreator")       # force-build on the old epoch
    knows_concat = e0.plane.cached_concat("Knows")

    raw_c = engine.topology.idm.raw_ids("Comment")
    raw_p = engine.topology.idm.raw_ids("Person")
    LakeCatalog(store).table("Comment_HasCreator_Person").append_files([{
        "src": raw_c[:40], "dst": raw_p[np.arange(40) % len(raw_p)],
        "creationDate": np.full(40, 20230101, dtype=np.int64),
    }])
    report = engine.advance()
    assert report.mode == "incremental"
    assert report.edge_files_added == 1 and report.edges_added == 40
    assert report.csr_extended == ["HasCreator"]

    e1 = engine.current_epoch()
    assert e1.epoch_id == e0.epoch_id + 1
    # the delta merged into a *new* CSR; the old epoch's index is untouched
    ext = e1.plane.csr("HasCreator", build=False)
    assert ext is not None and ext is not csr0
    assert csr0.n_edges + 40 == ext.n_edges
    # bit-identical to a from-scratch build over the new epoch's edges
    src, dst = e1.plane.concat_edges("HasCreator")
    ref = CSRIndex.from_arrays("HasCreator", src, dst,
                               e1.n_vertices("Comment"), e1.n_vertices("Person"))
    for attr in ("fwd_indptr", "fwd_dst", "fwd_eid",
                 "rev_indptr", "rev_src", "rev_eid"):
        np.testing.assert_array_equal(getattr(ext, attr), getattr(ref, attr))
    # untouched edge types carry their derived arrays forward by reference
    if knows_concat is not None:
        assert e1.plane.cached_concat("Knows") is knows_concat


def test_vertex_append_extends_idm_without_rebuild(store, ldbc, engine):
    topo_before = engine.topology
    n_real_before = engine.current_epoch().n_real_vertices("Comment")
    new_cids = _append_comments_and_edges(store, engine, ldbc, n_new=30)

    report = engine.advance()
    assert report.mode == "incremental"
    assert report.vertex_files_added == 1 and report.vertices_added == 30
    assert engine.topology is topo_before          # no rebuild happened

    e1 = engine.current_epoch()
    assert e1.n_real_vertices("Comment") == n_real_before + 30
    # the extended IDM resolves the new raw ids into the new epoch
    vset = engine.vset_from_raw_ids("Comment", new_cids, epoch=e1)
    assert vset.size() == 30
    # and their attributes + edges are queryable, bit-identical to cold start
    res = (Query(engine).vertices("Comment", raw_ids=new_cids)
           .hop("HasCreator", direction="out").run())
    assert res.n_edges_scanned == 30
    cold = GraphLakeEngine(store, ldbc_graph_schema(), materialize_topology=False)
    cold.startup()
    res_cold = (Query(cold).vertices("Comment", raw_ids=new_cids)
                .hop("HasCreator", direction="out").run())
    _assert_parity(res, res_cold)
    cold.close()


def test_removed_file_invalidates_exactly_its_units(store, ldbc, engine):
    # warm the cache across Knows edge chunks and Person vertex chunks
    (Query(engine).vertices("Person")
     .hop("Knows", direction="out", edge_where=gt("creationDate", 0)).run())
    victim = LakeCatalog(store).table("Person_Knows_Person").data_files()[0]
    assert any(k.startswith(victim + "::") for k in engine.cache.resident_keys())
    survivors_before = [k for k in engine.cache.resident_keys()
                        if not k.startswith(victim + "::")]

    LakeCatalog(store).table("Person_Knows_Person").delete_file(victim)
    report = engine.advance()
    assert report.mode == "incremental" and report.edge_files_removed == 1
    assert report.cache_units_evicted > 0

    resident = engine.cache.resident_keys()
    assert not any(k.startswith(victim + "::") for k in resident)
    # file-scoped means *only* that file: everything else stayed warm
    for k in survivors_before:
        assert k in resident
    # the epoch no longer scans the removed file's edges
    frame = engine.edge_scan(engine.all_vertices("Person"), "Knows")
    assert len(frame) == engine.current_epoch().n_edges("Knows")


def test_vertex_file_removal_falls_back_to_rebuild(store, ldbc, engine):
    old_topo = engine.topology
    n_before = engine.current_epoch().n_real_vertices("Person")
    victim_rows = None
    t = LakeCatalog(store).table("Person")
    victim = t.data_files()[0]
    from repro.lakehouse.columnfile import read_footer
    victim_rows = read_footer(store, victim).n_rows
    t.delete_file(victim)

    report = engine.advance()
    assert report.changed and report.mode == "rebuild"
    assert engine.topology is not old_topo
    e1 = engine.current_epoch()
    assert e1.n_real_vertices("Person") == n_before - victim_rows
    # engine still answers queries over the rebuilt topology; edges whose
    # source person was deleted hang off dangling vertices now, so a
    # real-vertex frontier scans exactly the surviving-source edges
    res = Query(engine).vertices("Person").hop("Knows", direction="out").run()
    assert res.epoch_id == e1.epoch_id
    n_live_src = sum(
        int((el.src_dense < e1.n_real_vertices("Person")).sum())
        for el in e1.all_edge_lists("Knows")
    )
    assert res.n_edges_scanned == n_live_src > 0


def test_accumulators_track_grown_dense_space(store, ldbc, engine):
    """After a vertex-append advance, a pre-existing accumulator's result
    view must still align with the result vset's (grown) dense space."""
    from repro.core.query import accum_sum

    def accum_query():
        return (Query(engine).vertices("Comment")
                .hop("HasCreator", direction="out",
                     accum=accum_sum("cnt", 1.0)).run())

    res0 = accum_query()                       # registers cnt at the old size
    sum0 = res0.accumulators["cnt"].sum()      # views share the live buffer:
    _append_comments_and_edges(store, engine, ldbc, n_new=30)  # snapshot now
    assert engine.advance().mode == "incremental"

    res1 = accum_query()
    # the accumulator view is sized to the new epoch's dense space, so
    # indexing it with the result vset's mask is always well-formed
    assert len(res1.accumulators["cnt"]) == len(res1.vset.mask)
    assert res1.accumulators["cnt"][res1.vset.mask].sum() > 0
    # both runs counted every comment once; the append added 30 edges
    assert res1.accumulators["cnt"].sum() == sum0 + ldbc.n_comments + 30


# ---------------------------------------------------------------------------
# concurrent advance: serialized, monotonic, no torn publish
# ---------------------------------------------------------------------------

def test_concurrent_advance_serialized_and_monotonic(store, ldbc, engine):
    """Racing advance() callers (the ingest epoch driver + the server's
    refresher + manual calls all share this entry point) must serialize:
    per commit round exactly one applies the diff, epoch ids stay strictly
    monotonic with no gaps, and a watcher never observes a torn epoch."""
    watch_errors = []
    seen_ids = []
    stop = threading.Event()

    def watch():
        last = 0
        while not stop.is_set():
            e = engine.current_epoch()
            if e.epoch_id < last:
                watch_errors.append(f"epoch went backwards: {e.epoch_id} < {last}")
                return
            if not (e.vertex_pins and e.edge_pins and e.idm is not None):
                watch_errors.append(f"torn epoch {e.epoch_id}: missing pins/idm")
                return
            last = e.epoch_id
            seen_ids.append(last)

    watcher = threading.Thread(target=watch)
    watcher.start()
    try:
        e_start = engine.current_epoch().epoch_id
        lake = LakeCatalog(store)
        raw_c = engine.topology.idm.raw_ids("Comment")
        raw_p = engine.topology.idm.raw_ids("Person")
        for rnd in range(3):
            lake.table("Comment_HasCreator_Person").append_files([{
                "src": raw_c[rnd * 10:(rnd + 1) * 10],
                "dst": raw_p[np.arange(10) % len(raw_p)],
                "creationDate": np.full(10, 20230101 + rnd, dtype=np.int64),
            }])
            barrier = threading.Barrier(4)
            reports, errors = [], []

            def advance_racing():
                barrier.wait()
                try:
                    reports.append(engine.advance())
                except Exception as ex:      # noqa: BLE001 — collected
                    errors.append(ex)

            threads = [threading.Thread(target=advance_racing)
                       for _ in range(4)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            assert not errors, errors
            # exactly one racer applied the diff; the rest no-op'd
            assert sum(1 for r in reports if r.changed) == 1
            assert engine.current_epoch().epoch_id == e_start + rnd + 1
    finally:
        stop.set()
        watcher.join()
    assert not watch_errors, watch_errors
    # the watcher saw a monotone id sequence ending at the final epoch
    assert seen_ids == sorted(seen_ids)


# ---------------------------------------------------------------------------
# delete_file -> advance: evicted data matches a cold start bit-for-bit
# ---------------------------------------------------------------------------

def test_edge_file_delete_advance_matches_cold_start(store, ldbc, engine):
    t = LakeCatalog(store).table("Comment_HasCreator_Person")
    victim = t.data_files()[1]
    victim_rows = None
    from repro.lakehouse.columnfile import read_footer
    victim_rows = read_footer(store, victim).n_rows
    n_before = engine.current_epoch().n_edges("HasCreator")

    t.delete_file(victim)
    report = engine.advance()
    assert report.changed and report.edge_files_removed == 1

    e1 = engine.current_epoch()
    assert e1.n_edges("HasCreator") == n_before - victim_rows
    res = Query(engine).vertices("Comment").hop(
        "HasCreator", edge_where=gt("creationDate", 0)).run()
    assert res.epoch_id == e1.epoch_id

    # the surviving epoch is bit-identical to an engine that never saw the
    # deleted file at all
    cold = GraphLakeEngine(store, ldbc_graph_schema(), materialize_topology=False)
    cold.startup()
    try:
        res_cold = Query(cold).vertices("Comment").hop(
            "HasCreator", edge_where=gt("creationDate", 0)).run()
        _assert_parity(res, res_cold)
    finally:
        cold.close()


# ---------------------------------------------------------------------------
# refcounting + retirement
# ---------------------------------------------------------------------------

def test_epoch_refcount_drain_and_retire(store, ldbc, engine):
    mgr = engine.epochs
    e0 = mgr.acquire()
    res_old = Query(engine).vertices("Comment").hop("HasCreator").run(epoch=e0)

    _append_comments_and_edges(store, engine, ldbc, n_new=20)
    assert engine.advance().changed
    e1 = engine.current_epoch()
    assert e1 is not e0 and not e0.retired   # still pinned by our acquire

    # in-flight work drains on the old epoch, bit-identical to before
    res_drain = Query(engine).vertices("Comment").hop("HasCreator").run(epoch=e0)
    _assert_parity(res_old, res_drain)
    res_new = Query(engine).vertices("Comment").hop("HasCreator").run()
    assert res_new.n_edges_scanned == res_old.n_edges_scanned + 20

    mgr.release(e0)                          # last ref gone -> delta buffers freed
    assert e0.retired and mgr.stats["retired"] >= 1
    assert not e0._edge_lists
    assert engine.current_epoch() is e1 and not e1.retired


# ---------------------------------------------------------------------------
# serving: background refresher
# ---------------------------------------------------------------------------

def test_server_background_refresh_picks_up_commits(store, ldbc, engine):
    def count_edges(eng):
        return Query(eng).vertices("Comment").hop("HasCreator").run().n_edges_scanned

    server = QueryServer(engine, {"count": count_edges},
                         ServerConfig(n_workers=1, refresh_interval_s=0.05))
    try:
        r0 = server.run_batch([("count", {})])[0]
        assert r0.ok and r0.value == ldbc.n_comments

        _append_comments_and_edges(store, engine, ldbc, n_new=15)
        deadline = time.monotonic() + 20.0
        while time.monotonic() < deadline and server.refresh_stats["advanced"] == 0:
            time.sleep(0.02)
        assert server.refresh_stats["advanced"] >= 1, server.refresh_stats
        assert server.refresh_stats["last_epoch"] == engine.current_epoch().epoch_id

        r1 = server.run_batch([("count", {})])[0]
        assert r1.ok and r1.value == ldbc.n_comments + 15
    finally:
        server.close()
    assert not server._refresher.is_alive()


# ---------------------------------------------------------------------------
# manifest re-materialization after advance (second-connection freshness)
# ---------------------------------------------------------------------------

def test_advance_rematerializes_manifest_for_second_connection(store, ldbc):
    eng = GraphLakeEngine(store, ldbc.schema, materialize_topology=True)
    eng.startup()
    try:
        assert eng.startup_mode == "first_connection"
        _append_comments_and_edges(store, eng, ldbc, n_new=12)
        report = eng.advance()
        assert report.changed and report.mode == "incremental"
        # the persisted topology followed the epoch: delta blobs + manifest
        assert report.rematerialized == "delta"
        res_a = Query(eng).vertices("Comment").hop(
            "HasCreator", edge_where=gt("creationDate", 20200101)).run()

        # a second connection takes the fast materialized path AND sees the
        # post-advance lake state — no stale blob, no full rebuild
        eng2 = GraphLakeEngine(store, ldbc.schema)
        eng2.startup()
        try:
            assert eng2.startup_mode == "second_connection"
            assert eng2.topology.n_edges() == eng.topology.n_edges()
            assert (eng2.topology.n_real_vertices("Comment")
                    == eng.topology.n_real_vertices("Comment"))
            res_b = Query(eng2).vertices("Comment").hop(
                "HasCreator", edge_where=gt("creationDate", 20200101)).run()
            _assert_parity(res_a, res_b)
            # its first advance() is a no-op: the manifest pinned the synced
            # snapshots, so nothing diffs
            r2 = eng2.advance()
            assert not r2.changed
        finally:
            eng2.close()
    finally:
        eng.close()


def test_advance_rematerialize_skipped_when_not_materializing(store, ldbc, engine):
    _append_comments_and_edges(store, engine, ldbc, n_new=8)
    report = engine.advance()
    assert report.changed and report.rematerialized == ""
    assert not store.exists("topology/MANIFEST.json")
