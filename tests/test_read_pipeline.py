"""Parallel chunk-pipelined read path (DESIGN.md §5): plan coverage,
pipelined-vs-sequential parity, per-gather dedup, IOPool leak fix."""

import numpy as np
import pytest

from repro.core.engine import GraphLakeEngine
from repro.core.cache.manager import CacheConfig
from repro.core.plan import ColumnBounds
from repro.core.primitives import read_edge_columns_pruned, read_vertex_columns_pruned
from repro.core.query import ExecOptions, Query, eq, gt
from repro.core.read_pipeline import ReadContext, plan_edge_read, plan_vertex_read
from repro.data.ldbc import generate_ldbc, ldbc_graph_schema
from repro.lakehouse.io_pool import IOPool
from repro.lakehouse.objectstore import ObjectStore, StoreConfig


@pytest.fixture(scope="module")
def lake(tmp_path_factory):
    root = tmp_path_factory.mktemp("pipe_lake")
    store = ObjectStore(StoreConfig(root=str(root)))
    generate_ldbc(store, scale_factor=0.004, n_files=2, row_group_rows=128)
    return store


@pytest.fixture(scope="module")
def engine(lake):
    eng = GraphLakeEngine(lake, ldbc_graph_schema(),
                          cache_config=CacheConfig(memory_budget_bytes=1 << 30))
    eng.startup()
    yield eng
    eng.close()


def _frames_equal(a, b):
    assert np.array_equal(a.u, b.u) and np.array_equal(a.v, b.v)
    assert set(a.columns) == set(b.columns)
    for k in a.columns:
        assert np.array_equal(a.columns[k], b.columns[k]), k


# ---------------------------------------------------------------------------
# planning
# ---------------------------------------------------------------------------

def test_fetch_plan_covers_all_surviving_chunks(engine):
    topo = engine.topology
    ids = engine.all_vertices("Comment").ids()
    plan = plan_vertex_read(topo, "Comment", ids, ["creationDate", "length"])
    # one request per (file, row group, column), rows+positions partition the
    # request exactly
    assert plan.n == len(ids)
    covered = np.zeros(len(ids), dtype=int)
    for req in plan.requests:
        assert req.kind == "vertex"
        assert len(req.rows) == len(req.pos)
        covered[req.pos] += 1
    assert (covered == len(plan.columns)).all()
    assert not plan.reject.any()


def test_fetch_plan_zone_map_pruning_upfront(engine):
    topo = engine.topology
    ids = engine.all_vertices("Comment").ids()
    hi = {"creationDate": ColumnBounds(lo=1e18, lo_strict=True)}  # nothing passes
    plan = plan_vertex_read(topo, "Comment", ids, ["creationDate"], bounds=hi)
    assert not plan.requests          # every chunk rejected at plan time
    assert plan.reject.all()

    eids = np.arange(topo.n_edges("HasCreator"), dtype=np.int64)
    eplan = plan_edge_read(topo, "HasCreator", eids, ["creationDate"], bounds=hi)
    assert not eplan.requests
    assert eplan.reject.all()


# ---------------------------------------------------------------------------
# pipelined-vs-sequential parity
# ---------------------------------------------------------------------------

def test_reader_parity_with_pool(engine):
    topo, cache = engine.topology, engine.cache
    rng = np.random.default_rng(5)
    ids = np.sort(rng.choice(engine.all_vertices("Comment").ids(), size=64,
                             replace=False))
    seq, rej_s = read_vertex_columns_pruned(
        topo, cache, "Comment", ids, ["creationDate", "length"])
    with IOPool(n_threads=4) as pool:
        par, rej_p = read_vertex_columns_pruned(
            topo, cache, "Comment", ids, ["creationDate", "length"], pool=pool)
        eids = np.sort(rng.choice(topo.n_edges("HasCreator"), size=64,
                                  replace=False)).astype(np.int64)
        eseq, _ = read_edge_columns_pruned(
            topo, cache, "HasCreator", eids, ["creationDate"])
        epar, _ = read_edge_columns_pruned(
            topo, cache, "HasCreator", eids, ["creationDate"], pool=pool)
    np.testing.assert_array_equal(rej_s, rej_p)
    for c in seq:
        np.testing.assert_array_equal(seq[c], par[c])
    np.testing.assert_array_equal(eseq["creationDate"], epar["creationDate"])


def test_query_parity_pipelined_vs_sequential(engine):
    dates = engine.read_vertex_column(
        "Comment", engine.all_vertices("Comment").ids(), "creationDate")
    thr = float(np.quantile(dates, 0.9))

    def q():
        return (Query(engine)
                .vertices("Comment")
                .hop("HasCreator", direction="out",
                     edge_where=gt("creationDate", thr),
                     target_where=eq("gender", "Female")))

    engine.cache.drop_all()
    res_seq = q().run(ExecOptions(pipeline=False))
    engine.cache.drop_all()
    res_pipe = q().run(ExecOptions(pipeline=True))
    engine.cache.drop_all()
    res_legacy = q().run(ExecOptions(pushdown=False, pipeline=True))

    for other in (res_pipe, res_legacy):
        assert res_seq.n_edges_scanned == other.n_edges_scanned
        assert np.array_equal(res_seq.vset.ids(), other.vset.ids())
        for fa, fb in zip(res_seq.frames, other.frames):
            _frames_equal(fa, fb)
    # pruning counters stay deterministic across the two execution modes
    assert res_seq.pruning["chunks_read"] == res_pipe.pruning["chunks_read"]
    assert res_seq.pruning["chunks_skipped"] == res_pipe.pruning["chunks_skipped"]
    assert res_seq.pruning["rows_decoded"] == res_pipe.pruning["rows_decoded"]


def test_explicit_pipeline_overrides_disabled_flag(lake, monkeypatch):
    """run(ExecOptions(pipeline=True)) must pipeline even under REPRO_OPTS=""
    (all flags off) — the flag is only the default for pipeline=None.  Regression: the
    executor used to re-check the flag and silently fall back to sequential,
    which made the benchmark's pinned pipelined arm measure nothing."""
    monkeypatch.setenv("REPRO_OPTS", "")
    eng = GraphLakeEngine(lake, ldbc_graph_schema(), enable_prefetch=False)
    eng.startup()
    try:
        q = (Query(eng).vertices("Comment")
             .hop("HasCreator", direction="out", edge_where=gt("creationDate", 0)))
        eng.cache.drop_all()
        tasks_before = eng.pool.stats["tasks"]
        res_default = q.run()                 # pipeline=None + flag off: sequential
        assert eng.pool.stats["tasks"] == tasks_before
        eng.cache.drop_all()
        res_forced = q.run(ExecOptions(pipeline=True))  # explicit override: pipelined
        assert eng.pool.stats["tasks"] > tasks_before
        assert res_default.n_edges_scanned == res_forced.n_edges_scanned
        for fa, fb in zip(res_default.frames, res_forced.frames):
            _frames_equal(fa, fb)
    finally:
        eng.close()


# ---------------------------------------------------------------------------
# per-gather dedup
# ---------------------------------------------------------------------------

def test_read_context_dedups_repeat_chunks(engine):
    topo, cache = engine.topology, engine.cache
    ids = engine.all_vertices("Person").ids()
    ctx = ReadContext()
    with IOPool(n_threads=4) as pool:
        first, _ = read_vertex_columns_pruned(
            topo, cache, "Person", ids, ["birthday"], pool=pool, ctx=ctx)
        hits_before = cache.stats["hits"]
        # second stage of the same gather touching the same chunks: served
        # from the context, never re-enters the cache manager
        second, _ = read_vertex_columns_pruned(
            topo, cache, "Person", ids, ["birthday"], pool=pool, ctx=ctx)
    assert cache.stats["hits"] == hits_before
    np.testing.assert_array_equal(first["birthday"], second["birthday"])


def test_self_loop_hop_fetches_each_chunk_once(engine):
    """Knows is Person->Person: the staged scan's U and V stages hit the same
    vertex files; the shared ReadContext must not fetch any chunk twice."""
    engine.cache.drop_all()
    fetches_before = engine.cache.stats["lake_fetches"]
    res = (Query(engine)
           .vertices("Person")
           .hop("Knows", direction="out",
                source_where=gt("birthday", 0),
                target_where=gt("birthday", 0))
           ).run(ExecOptions(pipeline=True))
    n_birthday_chunks = sum(
        1 for meta in engine.topology.vertex_file_metas.values()
        for c in meta.chunks if c.column == "birthday")
    fetched = engine.cache.stats["lake_fetches"] - fetches_before
    assert fetched <= n_birthday_chunks
    assert res.n_edges_scanned > 0


# ---------------------------------------------------------------------------
# IOPool: semaphore leak on executor rejection (ISSUE satellite)
# ---------------------------------------------------------------------------

def test_io_pool_submit_releases_slot_on_rejection():
    pool = IOPool(n_threads=2, max_in_flight=2)
    pool.close()  # executor shut down: submits now get rejected
    for _ in range(5):  # more rejections than in-flight slots
        with pytest.raises(RuntimeError):
            pool.submit(lambda: None)
    # every rejected submit released its slot; the semaphore still holds its
    # full budget (the old code leaked one permit per rejection and the third
    # submit would deadlock instead of raising)
    assert pool._sem._value == 2
