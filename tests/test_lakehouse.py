"""Unit + property tests for the lakehouse substrate."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.lakehouse.encoding import (
    Encoding,
    bit_width,
    choose_encoding,
    chunk_row_count,
    decode_column,
    encode_column,
    pack_bits,
    unpack_bits,
)
from repro.lakehouse.columnfile import (
    read_column_chunk,
    read_columns,
    read_footer,
    write_column_file,
)
from repro.lakehouse.io_pool import IOPool, prefetch_iter
from repro.lakehouse.objectstore import ObjectStore, StoreConfig
from repro.lakehouse.table import ColumnSpec, LakeCatalog, TableSchema
from repro.lakehouse.writer import write_table


@pytest.fixture
def store(tmp_path):
    return ObjectStore(StoreConfig(root=str(tmp_path / "lake")))


# ---------------------------------------------------------------------------
# encodings
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("encoding", list(Encoding))
@pytest.mark.parametrize(
    "arr",
    [
        np.arange(1000, dtype=np.int64),
        np.repeat(np.arange(10, dtype=np.int32), 100),
        np.zeros(17, dtype=np.int64),
        np.array([5], dtype=np.int64),
        np.array([], dtype=np.int64),
    ],
)
def test_int_roundtrip(encoding, arr):
    blob = encode_column(arr, encoding)
    out = decode_column(blob)
    np.testing.assert_array_equal(out, arr)
    assert chunk_row_count(blob) == len(arr)


@pytest.mark.parametrize("encoding", [Encoding.PLAIN, Encoding.RLE])
def test_float_roundtrip(encoding):
    rng = np.random.default_rng(0)
    arr = rng.standard_normal(513).astype(np.float32)
    out = decode_column(encode_column(arr, encoding))
    np.testing.assert_array_equal(out, arr)


@pytest.mark.parametrize("encoding", [Encoding.PLAIN, Encoding.RLE, Encoding.DICTIONARY])
def test_string_roundtrip(encoding):
    arr = np.array(["alice", "bob", "alice", "carol", "", "bob"], dtype=object)
    out = decode_column(encode_column(arr, encoding))
    assert out.tolist() == arr.tolist()


def test_bitpack_rejects_negative_and_strings():
    with pytest.raises(ValueError):
        encode_column(np.array([-1, 2]), Encoding.BITPACK)
    with pytest.raises(ValueError):
        encode_column(np.array(["x"], dtype=object), Encoding.BITPACK)


@pytest.mark.parametrize("encoding", list(Encoding))
def test_partial_decode_prefix(encoding):
    arr = np.arange(1000, dtype=np.int64) % 7
    blob = encode_column(arr, encoding)
    np.testing.assert_array_equal(decode_column(blob, row_limit=137), arr[:137])
    np.testing.assert_array_equal(decode_column(blob, row_limit=10_000), arr)


@settings(max_examples=30, deadline=None)
@given(
    st.lists(st.integers(min_value=0, max_value=2**40), min_size=0, max_size=200),
    st.sampled_from(list(Encoding)),
)
def test_property_int_roundtrip(values, encoding):
    arr = np.array(values, dtype=np.int64)
    np.testing.assert_array_equal(decode_column(encode_column(arr, encoding)), arr)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=2**30), min_size=1, max_size=300))
def test_property_pack_bits_roundtrip(values):
    arr = np.array(values, dtype=np.uint64)
    width = bit_width(int(arr.max()))
    np.testing.assert_array_equal(unpack_bits(pack_bits(arr, width), width, len(arr)), arr)


def test_choose_encoding_heuristics():
    assert choose_encoding(np.repeat(np.arange(4), 64)) == Encoding.RLE
    assert choose_encoding(np.random.default_rng(0).standard_normal(64)) == Encoding.PLAIN
    assert choose_encoding(np.array(["a", "b", "a", "b"] * 16, dtype=object)) == Encoding.DICTIONARY


# ---------------------------------------------------------------------------
# column files
# ---------------------------------------------------------------------------

def test_column_file_roundtrip(store):
    rng = np.random.default_rng(1)
    cols = {
        "id": np.arange(10_000, dtype=np.int64),
        "score": rng.standard_normal(10_000).astype(np.float32),
        "tag": np.array([f"t{i % 5}" for i in range(10_000)], dtype=object),
    }
    meta = write_column_file(store, "t/part-0.col", cols, row_group_rows=3000)
    assert meta.n_rows == 10_000
    assert len(meta.row_groups) == 4

    back = read_footer(store, "t/part-0.col")
    assert back.n_rows == 10_000
    got = read_columns(store, back, ["id", "score", "tag"])
    np.testing.assert_array_equal(got["id"], cols["id"])
    np.testing.assert_array_equal(got["score"], cols["score"])
    assert got["tag"].tolist() == cols["tag"].tolist()


def test_column_chunk_stats_and_partial(store):
    cols = {"id": np.arange(100, 300, dtype=np.int64)}
    meta = write_column_file(store, "t/p.col", cols, row_group_rows=50)
    c = meta.chunk("id", 1)
    assert c.min_value == 150 and c.max_value == 199
    part = read_column_chunk(store, meta, "id", 1, row_limit=10)
    np.testing.assert_array_equal(part, np.arange(150, 160))


# ---------------------------------------------------------------------------
# object store
# ---------------------------------------------------------------------------

def test_object_store_ranged_reads(store):
    store.put("a/b", b"0123456789")
    assert store.get("a/b", offset=2, length=3) == b"234"
    assert store.get("a/b", offset=-4) == b"6789"
    assert store.counters["get_requests"] == 2


def test_object_store_latency_model(tmp_path):
    s = ObjectStore(StoreConfig(root=str(tmp_path), latency_scale=1.0,
                                request_latency_s=0.003, bandwidth_bytes_per_s=1e9))
    s.put("k", b"x" * 1000)
    s.get("k")
    assert s.counters["simulated_wait_s"] > 0


# ---------------------------------------------------------------------------
# tables
# ---------------------------------------------------------------------------

def _person_schema():
    return TableSchema(
        name="Person",
        columns=[
            ColumnSpec("id", "int64", role="primary_key"),
            ColumnSpec("name", "str"),
            ColumnSpec("age", "int64"),
        ],
    )


def test_table_snapshots_and_append(store):
    cols = {
        "id": np.arange(100, dtype=np.int64),
        "name": np.array([f"p{i}" for i in range(100)], dtype=object),
        "age": np.arange(100, dtype=np.int64) % 90,
    }
    t = write_table(store, _person_schema(), cols, n_files=3)
    assert t.current_snapshot().n_files == 3
    assert t.current_snapshot().n_rows == 100

    more = {
        "id": np.arange(100, 120, dtype=np.int64),
        "name": np.array([f"p{i}" for i in range(100, 120)], dtype=object),
        "age": np.zeros(20, dtype=np.int64),
    }
    t.append_files([more])
    assert t.current_snapshot().n_files == 4
    assert t.current_snapshot().n_rows == 120
    # old snapshot is still readable (time travel)
    assert len(t.data_files(snapshot_id=1)) == 3


def test_table_delete_file(store):
    cols = {
        "id": np.arange(90, dtype=np.int64),
        "name": np.array(["x"] * 90, dtype=object),
        "age": np.zeros(90, dtype=np.int64),
    }
    t = write_table(store, _person_schema(), cols, n_files=3)
    victim = t.data_files()[1]
    t.delete_file(victim)
    assert victim not in t.data_files()
    assert t.current_snapshot().n_rows == 60


def test_catalog_state_polling(store):
    cols = {
        "id": np.arange(10, dtype=np.int64),
        "name": np.array(["x"] * 10, dtype=object),
        "age": np.zeros(10, dtype=np.int64),
    }
    write_table(store, _person_schema(), cols, n_files=2)
    cat = LakeCatalog(store)
    assert cat.list_tables() == ["Person"]
    snap_id, files = cat.table_state("Person")
    assert snap_id == 1 and len(files) == 2


# ---------------------------------------------------------------------------
# time travel + commit accounting
# ---------------------------------------------------------------------------

def _rows(n, offset=0):
    return {
        "id": np.arange(offset, offset + n, dtype=np.int64),
        "name": np.array([f"p{i}" for i in range(offset, offset + n)], dtype=object),
        "age": np.zeros(n, dtype=np.int64),
    }


def test_time_travel_historical_file_sets(store):
    t = write_table(store, _person_schema(), _rows(30), n_files=2)
    t.append_files([_rows(10, 100)])
    t.append_files([_rows(5, 200), _rows(5, 300)])

    snaps = t.snapshots()
    assert [s.snapshot_id for s in snaps] == [1, 2, 3]
    # each historical snapshot resolves its exact file set, forever
    files_1 = t.data_files(snapshot_id=1)
    files_2 = t.data_files(snapshot_id=2)
    files_3 = t.data_files(snapshot_id=3)
    assert len(files_1) == 2 and len(files_2) == 3 and len(files_3) == 5
    assert files_2[: len(files_1)] == files_1   # appends extend, never reorder
    assert files_3[: len(files_2)] == files_2
    # row accounting is cumulative per snapshot
    assert [s.n_rows for s in snaps] == [30, 40, 50]
    # a later commit does not disturb an already-resolved historical set
    t.append_files([_rows(1, 400)])
    assert t.data_files(snapshot_id=2) == files_2


def test_delete_file_row_and_file_accounting(store):
    t = write_table(store, _person_schema(), _rows(90), n_files=3)
    victim = t.data_files()[1]
    victim_rows = read_footer(store, victim).n_rows
    snap = t.delete_file(victim)
    assert snap.n_files == 2
    assert snap.n_rows == 90 - victim_rows
    assert victim not in t.data_files()
    # the old snapshot still sees the victim (logical delete, time travel)
    assert victim in t.data_files(snapshot_id=1)
    # and the physical object survives for readers pinned to old snapshots
    assert store.exists(victim)


def test_version_monotone_under_sequential_commits(store):
    t = write_table(store, _person_schema(), _rows(10), n_files=1)
    assert t.current_version() == 2   # create() wrote v1, first commit v2
    for i in range(5):
        before = t.current_version()
        snap = t.append_files([_rows(2, 1000 + 10 * i)])
        assert t.current_version() == before + 1      # exactly one step
        assert snap.snapshot_id == len(t.snapshots())  # ids are 1..N, dense
    ids = [s.snapshot_id for s in t.snapshots()]
    assert ids == list(range(1, len(ids) + 1))


# ---------------------------------------------------------------------------
# conditional put + concurrent committers
# ---------------------------------------------------------------------------

def test_put_if_semantics(store):
    assert store.put_if("k", b"v1", expected=None)          # create if absent
    assert not store.put_if("k", b"v2", expected=None)      # already exists
    assert not store.put_if("k", b"v2", expected=b"wrong")  # stale expectation
    assert store.get("k") == b"v1"
    assert store.put_if("k", b"v2", expected=b"v1")         # CAS succeeds
    assert store.get("k") == b"v2"
    assert store.counters["cas_failures"] == 2


def test_concurrent_committers_drop_no_snapshots(store):
    """The ISSUE 4 commit-race regression: racing append_files must never
    drop a snapshot (the old unguarded VERSION read-modify-write did)."""
    import threading

    t = write_table(store, _person_schema(), _rows(10), n_files=1)
    n_threads, commits_each = 4, 3
    snaps, errors = [], []
    lock = threading.Lock()

    def committer(tid):
        try:
            for i in range(commits_each):
                s = LakeCatalog(store).table("Person").append_files(
                    [_rows(5, 10_000 + 1000 * tid + 10 * i)])
                with lock:
                    snaps.append(s)
        except Exception as e:  # pragma: no cover - surfaced by the assert
            with lock:
                errors.append(e)

    threads = [threading.Thread(target=committer, args=(k,)) for k in range(n_threads)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert not errors

    total = n_threads * commits_each
    final = t.snapshots()
    assert len(final) == 1 + total                      # nothing dropped
    assert [s.snapshot_id for s in final] == list(range(1, 2 + total))
    assert t.current_version() == 2 + total             # one step per commit
    assert t.current_snapshot().n_rows == 10 + 5 * total
    # every committer's data file made it into the final manifest
    assert len(set(t.data_files())) == 1 + total
    # distinct snapshot ids were handed back to the committers
    assert len({s.snapshot_id for s in snaps}) == total


# ---------------------------------------------------------------------------
# I/O pool
# ---------------------------------------------------------------------------

def test_io_pool_pipelined_order():
    with IOPool(n_threads=4) as pool:
        items = list(range(20))
        out = pool.map_pipelined(items, fetch=lambda i: i * 2, compute=lambda i, v: v + 1)
    assert out == [i * 2 + 1 for i in range(20)]


def test_io_pool_prefetch_iter():
    with IOPool(n_threads=2) as pool:
        got = list(prefetch_iter(pool, range(7), fetch=lambda i: i * i, depth=3))
    assert got == [(i, i * i) for i in range(7)]


def test_io_pool_backup_fetch():
    import time as _time
    calls = []

    def slow():
        calls.append(1)
        if len(calls) == 1:
            _time.sleep(0.3)
        return 42

    with IOPool(n_threads=2) as pool:
        assert pool.fetch_with_backup(slow, backup_after_s=0.05) == 42
    assert pool.stats["backup_fetches"] == 1


# ---------------------------------------------------------------------------
# orphan-version janitor (crash between metadata CAS win and VERSION swap)
# ---------------------------------------------------------------------------

class _CommitCrash(RuntimeError):
    pass


def _crash_next_version_swap(store):
    """Arm the store so the next VERSION write dies *after* the metadata CAS
    won — the exact crash window the janitor exists for."""
    orig_put_if = store.put_if
    state = {"armed": True}

    def crashing_put_if(key, data, expected):
        if state["armed"] and key.endswith("metadata/VERSION"):
            state["armed"] = False
            raise _CommitCrash(key)
        return orig_put_if(key, data, expected)

    store.put_if = crashing_put_if
    return lambda: setattr(store, "put_if", orig_put_if)


def test_orphan_version_janitor_recovers_wedged_table(store):
    t = write_table(store, _person_schema(), _rows(10), n_files=1)
    v_before = t.current_version()
    n_snaps = len(t.snapshots())

    restore = _crash_next_version_swap(store)
    try:
        with pytest.raises(_CommitCrash):
            t.append_files([_rows(4, 500)])
    finally:
        restore()

    # wedged: the crashed committer's metadata version exists but VERSION
    # still points below it — readers see the old snapshot, and without the
    # janitor every future commit would lose its CAS forever
    assert t.current_version() == v_before
    assert store.exists(t._meta_key(v_before + 1))
    assert len(t.snapshots()) == n_snaps

    # the next commit rolls the orphan forward and lands on top of it:
    # BOTH snapshots (the crashed one and the new one) survive
    snap = t.append_files([_rows(3, 900)])
    assert t.current_version() == v_before + 2
    snaps = t.snapshots()
    assert [s.snapshot_id for s in snaps] == list(range(1, len(snaps) + 1))
    assert len(snaps) == n_snaps + 2
    assert snap.n_rows == 10 + 4 + 3
    # the crashed commit's data files are visible in the current file set
    total_rows = sum(read_footer(store, k).n_rows for k in t.data_files())
    assert total_rows == 17


def test_recover_orphan_version_direct_and_noop(store):
    t = write_table(store, _person_schema(), _rows(6), n_files=1)
    assert t.recover_orphan_version() == 0      # nothing orphaned

    restore = _crash_next_version_swap(store)
    try:
        with pytest.raises(_CommitCrash):
            t.append_files([_rows(2, 700)])
    finally:
        restore()

    rolled = t.recover_orphan_version()
    assert rolled == 1
    # the recovered snapshot is now the table head, no commit needed
    assert len(t.snapshots()) == 2
    assert t.current_snapshot().n_rows == 8
    assert t.recover_orphan_version() == 0      # idempotent
