"""Tests: BI query suite, query server, neighbor sampler."""

import numpy as np
import pytest

from repro.core.bi_queries import BI_QUERIES
from repro.core.engine import GraphLakeEngine
from repro.data.ldbc import generate_ldbc
from repro.data.sampler import NeighborSampler
from repro.lakehouse.objectstore import ObjectStore, StoreConfig
from repro.serving.server import QueryServer, ServerConfig, latency_stats


@pytest.fixture(scope="module")
def engine(tmp_path_factory):
    store = ObjectStore(StoreConfig(root=str(tmp_path_factory.mktemp("lake"))))
    generate_ldbc(store, scale_factor=0.004, n_files=3, row_group_rows=512)
    eng = GraphLakeEngine(store, __import__(
        "repro.data.ldbc", fromlist=["ldbc_graph_schema"]).ldbc_graph_schema())
    eng.startup()
    yield eng
    eng.close()


@pytest.mark.parametrize("name", list(BI_QUERIES))
def test_bi_queries_run(engine, name):
    out = BI_QUERIES[name](engine)
    assert isinstance(out, dict) and out
    for v in out.values():
        assert np.isfinite(v)


def test_bi1_nontrivial(engine):
    out = BI_QUERIES["bi1"](engine, tag_name="Music", date=20090101)
    assert out["total_comments"] > 0
    assert out["n_persons"] > 0


def test_query_server_batch(engine):
    server = QueryServer(engine, BI_QUERIES, ServerConfig(n_workers=2))
    try:
        reqs = [("bi1", {"date": 20100101 + i}) for i in range(4)]
        reqs += [("bi4", {"city": f"city_{i}"}) for i in range(4)]
        results = server.run_batch(reqs)
        assert all(r.ok for r in results), [r.error for r in results]
        stats = latency_stats(results)
        assert stats["count"] == 8 and stats["p99_s"] >= stats["p50_s"]
    finally:
        server.close()


def test_query_server_error_isolated(engine):
    def bad(engine):
        raise RuntimeError("boom")
    server = QueryServer(engine, {"bad": bad, **BI_QUERIES})
    try:
        r = server.run_batch([("bad", {}), ("bi3", {})])
        assert not r[0].ok and "boom" in r[0].error
        assert r[1].ok
    finally:
        server.close()


# ---------------------------------------------------------------------------
# neighbor sampler
# ---------------------------------------------------------------------------

def _random_graph(n=200, e=2000, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, n, e), rng.integers(0, n, e), n


def test_sampler_shapes_and_validity():
    src, dst, n = _random_graph()
    s = NeighborSampler(src, dst, n)
    seeds = np.arange(10)
    sub = s.sample(seeds, fanout=(5, 3), n_pad=256, e_pad=512, seed=1)
    assert sub.src.shape == (512,) and sub.node_ids.shape == (256,)
    live = sub.edge_mask.sum()
    assert 0 < live <= 10 * 5 + 10 * 5 * 3
    # compact ids in range; seed rows resolve to the original seeds
    assert sub.src[sub.edge_mask].max() < sub.node_mask.sum()
    np.testing.assert_array_equal(sub.node_ids[sub.seed_rows], seeds)


def test_sampler_edges_exist_in_graph():
    src, dst, n = _random_graph(seed=3)
    edge_set = set(zip(src.tolist(), dst.tolist()))
    s = NeighborSampler(src, dst, n)
    sub = s.sample(np.arange(5), fanout=(4,), n_pad=64, e_pad=64, seed=2)
    for cs, cd in zip(sub.src[sub.edge_mask], sub.dst[sub.edge_mask]):
        orig = (int(sub.node_ids[cd]), int(sub.node_ids[cs]))
        # sampler emits neighbor->node (message direction): original edge is
        # (node -> neighbor) in the CSR
        assert orig in edge_set


def test_sampler_determinism():
    src, dst, n = _random_graph(seed=4)
    s = NeighborSampler(src, dst, n)
    a = s.sample(np.arange(8), (6, 2), 128, 256, seed=9)
    b = s.sample(np.arange(8), (6, 2), 128, 256, seed=9)
    np.testing.assert_array_equal(a.src, b.src)
    np.testing.assert_array_equal(a.node_ids, b.node_ids)


def test_sampler_respects_fanout_cap():
    # star graph: hub connects to everyone; fanout must cap samples
    n = 100
    src = np.zeros(n - 1, dtype=np.int64)
    dst = np.arange(1, n, dtype=np.int64)
    s = NeighborSampler(src, dst, n)
    sub = s.sample(np.array([0]), fanout=(10,), n_pad=32, e_pad=32, seed=0)
    assert sub.edge_mask.sum() == 10
