"""Per-architecture smoke tests: REDUCED config, one step per cell kind on
CPU, asserting output shapes and no NaNs (assignment requirement)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_arch

LM_ARCHS = [a for a in ARCH_IDS if get_arch(a).family == "lm"]
GNN_ARCHS = [a for a in ARCH_IDS if get_arch(a).family == "gnn"]
REC_ARCHS = [a for a in ARCH_IDS if get_arch(a).family == "recsys"]


def _finite_tree(tree) -> bool:
    return all(
        bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(tree)
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating)
    )


@pytest.mark.parametrize("arch_id", LM_ARCHS)
def test_lm_train_smoke(arch_id):
    arch = get_arch(arch_id)
    cell = arch.shapes()[0]
    assert cell.kind == "train"
    state = arch.init_state(jax.random.PRNGKey(0), cell, reduced=True)
    batch = arch.example_batch(cell, reduced=True)
    step = jax.jit(arch.make_step(cell, reduced=True))
    state, metrics = step(state, batch)
    state, metrics = step(state, batch)
    assert float(metrics["loss"]) > 0 and np.isfinite(float(metrics["loss"]))
    assert _finite_tree(state["params"])
    assert int(state["step"]) == 2


@pytest.mark.parametrize("arch_id", LM_ARCHS)
def test_lm_prefill_decode_smoke(arch_id):
    arch = get_arch(arch_id)
    cells = {c.name: c for c in arch.shapes()}
    pre, dec = cells["prefill_32k"], cells["decode_32k"]

    state = arch.init_state(jax.random.PRNGKey(0), pre, reduced=True)
    batch = arch.example_batch(pre, reduced=True)
    logits, caches = jax.jit(arch.make_step(pre, reduced=True))(state, batch)
    cfg = arch.config(reduced=True)
    assert logits.shape == (batch["tokens"].shape[0], cfg.vocab)
    assert _finite_tree(logits)

    dstate = arch.init_state(jax.random.PRNGKey(0), dec, reduced=True)
    dbatch = arch.example_batch(dec, reduced=True)
    dlogits, dstate2 = jax.jit(arch.make_step(dec, reduced=True))(dstate, dbatch)
    assert dlogits.shape == (dbatch["token"].shape[0], cfg.vocab)
    assert _finite_tree(dlogits)
    # cache must actually change at the written position
    assert jax.tree.structure(dstate2["caches"]) == jax.tree.structure(dstate["caches"])


def test_lm_long500k_skip_documented():
    for arch_id in LM_ARCHS:
        cell = [c for c in get_arch(arch_id).shapes() if c.name == "long_500k"][0]
        assert cell.skip and "full-softmax" in cell.skip


@pytest.mark.parametrize("arch_id", GNN_ARCHS)
@pytest.mark.parametrize("cell_name", ["full_graph_sm", "molecule"])
def test_gnn_train_smoke(arch_id, cell_name):
    arch = get_arch(arch_id)
    cell = {c.name: c for c in arch.shapes()}[cell_name]
    state = arch.init_state(jax.random.PRNGKey(0), cell, reduced=True)
    batch = arch.example_batch(cell, reduced=True)
    batch.pop("n_graphs", None)
    step = jax.jit(arch.make_step(cell, reduced=True))
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert _finite_tree(state["params"])


@pytest.mark.parametrize("arch_id", GNN_ARCHS)
def test_gnn_loss_decreases(arch_id):
    arch = get_arch(arch_id)
    cell = arch.shapes()[0]
    state = arch.init_state(jax.random.PRNGKey(0), cell, reduced=True)
    batch = arch.example_batch(cell, reduced=True)
    batch.pop("n_graphs", None)
    step = jax.jit(arch.make_step(cell, reduced=True))
    losses = []
    for _ in range(8):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], losses


@pytest.mark.parametrize("arch_id", REC_ARCHS)
def test_recsys_train_and_serve_smoke(arch_id):
    arch = get_arch(arch_id)
    cells = {c.name: c for c in arch.shapes()}
    tr = cells["train_batch"]
    state = arch.init_state(jax.random.PRNGKey(0), tr, reduced=True)
    batch = arch.example_batch(tr, reduced=True)
    step = jax.jit(arch.make_step(tr, reduced=True))
    losses = []
    for _ in range(10):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0]

    sv = cells["serve_p99"]
    sstate = {"params": state["params"]}
    sbatch = arch.example_batch(sv, reduced=True)
    scores = jax.jit(arch.make_step(sv, reduced=True))(sstate, sbatch)
    assert scores.shape[0] == sbatch["idx_single"].shape[0]
    assert bool(((scores >= 0) & (scores <= 1)).all())


def test_all_cells_have_specs():
    """Every non-skipped cell yields consistent batch specs + shardable dims."""
    for arch_id in ARCH_IDS:
        arch = get_arch(arch_id)
        for cell in arch.shapes():
            if cell.skip:
                continue
            specs = arch.batch_specs(cell, reduced=False)
            assert specs, (arch_id, cell.name)
            for k, s in specs.items():
                assert all(d > 0 for d in s.shape), (arch_id, cell.name, k)


def test_param_counts_match_scale():
    """Analytic param counts are in the advertised ballpark."""
    ds = get_arch("deepseek-v2-lite-16b").config(False).param_count()
    assert 14e9 < ds < 18e9, ds
    phi = get_arch("phi3.5-moe-42b-a6.6b").config(False).param_count()
    assert 38e9 < phi < 46e9, phi
    q = get_arch("qwen2-1.5b").config(False).param_count()
    assert 1.1e9 < q < 1.9e9, q
    cq = get_arch("codeqwen1.5-7b").config(False).param_count()
    assert 6e9 < cq < 8.5e9, cq
    # active params for phi3.5: ~6.6b
    phi_a = get_arch("phi3.5-moe-42b-a6.6b").config(False).active_param_count()
    assert 5.5e9 < phi_a < 8e9, phi_a


def test_kv_int8_decode_within_tolerance(monkeypatch):
    """int8 KV caches (perf flag kv_int8): decode logits within 5% of the
    full-precision forward (per-vector symmetric quantization)."""
    import jax
    monkeypatch.setenv("REPRO_OPTS", "kv_int8")
    from repro.models import transformer as tf

    arch = get_arch("qwen2-1.5b")
    cfg = arch.config(reduced=True)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    caches = tf.init_caches(cfg, 2, 16)
    assert isinstance(caches[0], tuple)           # quantized structure
    _, caches = tf.prefill_step(cfg, params, toks[:, :8], caches)
    lg, _ = tf.decode_step(cfg, params, caches, toks[:, 8:9], jnp.asarray(8))
    full, _, _ = tf.forward(cfg, params, toks[:, :9])
    rel = float(jnp.abs(lg - full[:, -1]).max()) / float(jnp.abs(full[:, -1]).max())
    assert rel < 0.05, rel
