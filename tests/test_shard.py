"""Shard fabric: epoch-consistent scatter-gather across graph shards
(DESIGN.md §13).

Pins the subsystem's contract:

- ownership is a pure block-hash partition (every dense id owned by exactly
  one live shard; append-stable; re-sharded on rebuild/disconnect);
- a sharded GSQL run is **bit-identical** to the single-engine run — vset,
  accumulators, every frame row (u, v, eid, columns) in the same order;
- ``advance()`` works on sharded engines: deltas route to owning shards,
  upsert rewrites trigger a delta re-shard, and the version-suffixed CSR
  blobs give second connections the fast path after advances;
- a concurrent ``advance()`` never tears an in-flight scatter-gather:
  every result is bit-consistent with exactly one published epoch;
- retirement/disconnect clears per-shard delta buffers, armed lookup plans
  and shard views (no leaked refs);
- the ingest committer rejects dangling edge upserts with the typed
  :class:`~repro.errors.DanglingEdgeError`;
- the server's wire surface (``handle()``) serves vertices/neighbors/
  queries with per-route stats and a fabric health section.
"""

import threading

import numpy as np
import pytest

from repro.core.bi_queries import BI_GSQL, install_bi_queries
from repro.core.engine import GraphLakeEngine
from repro.data.ldbc import generate_ldbc, ldbc_graph_schema
from repro.errors import DanglingEdgeError
from repro.gsql.session import connect
from repro.lakehouse.objectstore import ObjectStore, StoreConfig
from repro.lakehouse.table import LakeCatalog
from repro.shard import (
    ShardFabric,
    ShardMap,
    merge_frames,
    shard_csr_from_bytes,
    shard_csr_key,
    shard_csr_to_bytes,
    slice_csr,
)

BI_PARAMS = {
    "bi1": {"tag": "Music", "date": 20100101},
    "bi2": {"lo": 20120101, "hi": 20151231},
    "bi3": {"min_len": 50},
    "bi4": {"city": "city_1"},
    "bi5": {"min_degree": 3, "date": 20100101},
}

# small lake -> small dense spaces: shrink ownership blocks so every type
# actually spans several blocks and shards see non-trivial slices
BLOCK_BITS = 4


@pytest.fixture(scope="module")
def lake(tmp_path_factory):
    store = ObjectStore(StoreConfig(root=str(tmp_path_factory.mktemp("lake"))))
    generate_ldbc(store, scale_factor=0.004, n_files=3, row_group_rows=512)
    return store


def _connect(lake, **kw):
    # each session gets its own store handle over the same lake root
    return connect(ObjectStore(StoreConfig(root=lake.config.root)),
                   ldbc_graph_schema(), **kw)


@pytest.fixture(scope="module")
def solo(lake):
    s = _connect(lake)
    install_bi_queries(s)
    yield s
    s.close()


@pytest.fixture(scope="module")
def sharded(lake):
    s = _connect(lake, shards=4, shard_block_bits=BLOCK_BITS)
    install_bi_queries(s)
    yield s
    s.close()


def assert_parity(a, b, label=""):
    """Full bit-parity of two QueryResults: work accounting, vset,
    accumulators, and every frame row in the same order."""
    assert a.n_edges_scanned == b.n_edges_scanned, label
    assert np.array_equal(a.vset.ids(), b.vset.ids()), label
    for k in a.accumulators:
        assert np.array_equal(a.accumulators[k], b.accumulators[k]), (label, k)
    assert len(a.frames) == len(b.frames), label
    for fa, fb in zip(a.frames, b.frames):
        assert np.array_equal(fa.u, fb.u), label
        assert np.array_equal(fa.v, fb.v), label
        if fa.eid is not None and fb.eid is not None:
            assert np.array_equal(fa.eid, fb.eid), label
        assert set(fa.columns) == set(fb.columns), label
        for k in fa.columns:
            assert fa.columns[k].dtype == fb.columns[k].dtype, (label, k)
            assert np.array_equal(fa.columns[k], fb.columns[k]), (label, k)


# ---------------------------------------------------------------- ownership


def test_ownership_is_a_partition():
    smap = ShardMap.fresh(4, block_bits=3)
    ids = np.arange(10_000, dtype=np.int64)
    owners = smap.owner_of("Person", ids)
    assert set(np.unique(owners)) <= set(smap.live)
    # every id owned by exactly one shard: masks partition the space
    masks = [smap.owned_mask("Person", len(ids), sid) for sid in smap.live]
    assert np.array_equal(np.sum(masks, axis=0), np.ones(len(ids)))
    # block granularity: ids in one block share an owner
    assert len(np.unique(owners[: 1 << 3])) == 1
    # append-stability: extending the space never moves existing owners
    again = smap.owner_of("Person", np.arange(20_000, dtype=np.int64))
    assert np.array_equal(again[:10_000], owners)
    # different vertex types salt differently (not all identical layouts)
    other = smap.owner_of("Comment", ids)
    assert not np.array_equal(owners, other)


def test_owners_of_range_covers_every_owner():
    smap = ShardMap.fresh(4, block_bits=3)
    lo, hi = 37, 4_221
    owners = set(smap.owners_of_range("Tag", lo, hi))
    exact = set(np.unique(smap.owner_of("Tag", np.arange(lo, hi))).tolist())
    assert exact <= owners


def test_resharded_bumps_version_and_drops_dead():
    smap = ShardMap.fresh(4)
    survivor = smap.resharded(live=(0, 2, 3))
    assert survivor.version == smap.version + 1
    assert survivor.live == (0, 2, 3)
    owners = survivor.owner_of("Person", np.arange(5_000, dtype=np.int64))
    assert 1 not in set(np.unique(owners).tolist())


# ---------------------------------------------------------------- sliced CSR


def test_slice_csr_partitions_edges_and_roundtrips(solo):
    csr = solo.engine.current_epoch().plane.csr("Knows")
    smap = ShardMap.fresh(3, block_bits=BLOCK_BITS)
    total_fwd = 0
    for sid in smap.live:
        src_owned = smap.owned_mask("Person", csr.n_src, sid)
        dst_owned = smap.owned_mask("Person", csr.n_dst, sid)
        part = slice_csr(csr, src_owned, dst_owned)
        total_fwd += len(part.fwd_dst)
        # global eids survive slicing untouched
        assert set(part.fwd_eid.tolist()) <= set(csr.fwd_eid.tolist())
        blob = shard_csr_to_bytes(part)
        back = shard_csr_from_bytes(blob)
        assert back.edge_type == part.edge_type
        for attr in ("fwd_indptr", "fwd_dst", "fwd_eid",
                     "rev_indptr", "rev_src", "rev_eid"):
            assert np.array_equal(getattr(back, attr), getattr(part, attr))
    # fwd adjacency partitioned by src ownership: no edge lost or doubled
    assert total_fwd == len(csr.fwd_dst)
    smap4 = ShardMap.fresh(4)
    key = shard_csr_key("Knows", 3, 1, smap4)
    assert key == f"topology/csr/Knows-v3.s1of4.m{smap4.slice_token()}.csr"
    # the key is content-addressed by the slice-defining map state: a
    # disconnect (new live tuple) at the SAME topology version must address
    # different blobs, while an independent fresh fabric with the same live
    # set gets the same key (the second-connection fast path)
    assert shard_csr_key("Knows", 3, 1, smap4.resharded(live=(0, 1, 3))) != key
    assert shard_csr_key("Knows", 3, 1, ShardMap.fresh(4)) == key


def test_merge_frames_reconstructs_global_order():
    from repro.core.primitives import EdgeFrame

    eid = np.array([4, 0, 2, 1, 3], dtype=np.int64)
    u = np.array([40, 0, 20, 10, 30], dtype=np.int64)
    # partition rows arbitrarily, including an empty part
    parts = []
    for rows in ([1, 3], [0, 2, 4], []):
        idx = np.array(rows, dtype=np.int64)
        parts.append(EdgeFrame(u=u[idx], v=u[idx] + 1,
                               u_type="Person", v_type="Person",
                               columns={"w": (u * 2)[idx]}, eid=eid[idx]))
    merged = merge_frames(parts)
    order = np.argsort(eid, kind="stable")
    assert np.array_equal(merged.eid, eid[order])
    assert np.array_equal(merged.u, u[order])
    assert merged.columns["w"].dtype == np.int64
    assert np.array_equal(merged.columns["w"], (u * 2)[order])


# ---------------------------------------------------------------- parity


@pytest.mark.parametrize("n_shards", [2, 4])
def test_bi_suite_bit_parity(lake, solo, n_shards):
    s = _connect(lake, shards=n_shards, shard_block_bits=BLOCK_BITS)
    install_bi_queries(s)
    try:
        fab = s.engine._shard_fabric
        for name in BI_GSQL:
            a = solo.query(name, **BI_PARAMS[name])
            b = s.query(name, **BI_PARAMS[name])
            assert_parity(a, b, name)
        assert fab.stats["scatter_gathers"] > 0
        assert fab.stats["worker_scans"] > fab.stats["scatter_gathers"]
    finally:
        s.close()


def test_batched_path_parity(solo, sharded):
    plist = [{"tag": "Music", "date": 20100101},
             {"tag": "Sports", "date": 20090101}]
    for qa, qb in zip(solo.query_batch("bi1", plist),
                      sharded.query_batch("bi1", plist)):
        assert_parity(qa, qb, "batch")


def test_connect_shards_flag(lake):
    s = _connect(lake)
    assert s.engine._shard_fabric is None
    s.close()
    s = _connect(lake, shards=2, shard_block_bits=BLOCK_BITS)
    fab = s.engine._shard_fabric
    assert fab is not None and fab.smap.n_shards == 2
    snap = fab.stats_snapshot()
    assert snap["live_shards"] == [0, 1]
    s.close()
    assert s.engine._shard_fabric is None   # close() tears the fabric down


def test_fabric_requires_two_shards(lake):
    s = _connect(lake)
    try:
        with pytest.raises(ValueError):
            ShardFabric.attach(s.engine, 1)
    finally:
        s.close()


# ---------------------------------------------------------------- advance


def _stage_append(store, engine, n_new, seed=11):
    """bench_refresh-style incremental append: new Comments + HasCreator."""
    rng = np.random.default_rng(seed)
    raw = engine.topology.idm.raw_ids("Comment")
    new_cids = raw.max() + 10 * (1 + np.arange(n_new, dtype=np.int64))
    lake = LakeCatalog(store)
    lake.table("Comment").append_files([{
        "id": new_cids,
        "creationDate": rng.integers(20230101, 20231231, n_new).astype(np.int64),
        "length": rng.integers(1, 2000, n_new).astype(np.int64),
        "browserUsed": np.array(["Chrome"] * n_new, dtype=object),
    }])
    person_raw = engine.topology.idm.raw_ids("Person")
    lake.table("Comment_HasCreator_Person").append_files([{
        "src": new_cids,
        "dst": person_raw[rng.integers(0, len(person_raw), n_new)],
        "creationDate": rng.integers(20230101, 20231231, n_new).astype(np.int64),
    }])
    return new_cids


def test_sharded_advance_append_then_upsert(tmp_path):
    """The acceptance scenario: a 4-shard fabric applies an incremental
    append + a row-level upsert delta; every subsequent GSQL / lookup /
    batched result is bit-identical to the single-engine run on the same
    epoch, and the per-epoch CSR blobs give a second connection the fast
    path."""
    store = ObjectStore(StoreConfig(root=str(tmp_path / "lake")))
    generate_ldbc(store, scale_factor=0.004, n_files=3, row_group_rows=512)
    s = connect(ObjectStore(StoreConfig(root=store.config.root)),
                ldbc_graph_schema(), shards=4, shard_block_bits=BLOCK_BITS)
    install_bi_queries(s)
    fab = s.engine._shard_fabric
    s.query("bi3", min_len=50)      # warm a fabric epoch pre-advance

    # -- incremental append delta
    _stage_append(store, s.engine, n_new=48)
    rep = s.engine.advance()
    assert rep.changed and rep.mode == "incremental"
    assert fab.stats["syncs"] == 1
    assert fab.stats["incremental_rearms"] == 1
    assert fab.stats["delta_files_routed"] > 0
    assert fab.current().base.epoch_id == rep.to_epoch

    # -- row-level upsert delta (copy-on-write rewrite -> delta re-shard)
    cid = int(s.engine.topology.idm.raw_ids("Comment")[0])
    LakeCatalog(store).table("Comment").upsert_rows(
        {"id": np.array([cid], dtype=np.int64),
         "creationDate": np.array([20230505], dtype=np.int64),
         "length": np.array([31337], dtype=np.int64),
         "browserUsed": np.array(["Edge"], dtype=object)},
        key_columns=["id"])
    ver_before = fab.smap.version
    rep2 = s.engine.advance()
    assert rep2.changed and rep2.mode == "rebuild"
    assert fab.stats["delta_reshards"] >= 1
    assert fab.smap.version == ver_before + 1

    # -- a cold single engine on the advanced lake takes the CSR fast path
    solo = connect(ObjectStore(StoreConfig(root=store.config.root)),
                   ldbc_graph_schema())
    install_bi_queries(solo)
    assert solo.engine.startup_mode == "second_connection"
    try:
        for name in BI_GSQL:
            assert_parity(solo.query(name, **BI_PARAMS[name]),
                          s.query(name, **BI_PARAMS[name]), name)
        plist = [{"min_len": 50}, {"min_len": 100}]
        for qa, qb in zip(solo.query_batch("bi3", plist),
                          s.query_batch("bi3", plist)):
            assert_parity(qa, qb, "batch-post-advance")
        ga = solo.get_vertex("Comment", cid, columns=("length",))
        gb = s.get_vertex("Comment", cid, columns=("length",))
        assert ga == gb and int(ga["length"]) == 31337
        na = solo.neighbors("HasCreator", cid)
        nb = s.neighbors("HasCreator", cid)
        assert np.array_equal(np.sort(np.asarray(na)), np.sort(np.asarray(nb)))
    finally:
        solo.close()
        s.close()


def test_concurrent_advance_during_scatter_gather(tmp_path):
    """advance() racing in-flight scatter-gathers: epoch ids are monotonic,
    no result is torn across epochs (each matches the single-engine run of
    exactly one published epoch), and the drained state is bit-identical."""
    store = ObjectStore(StoreConfig(root=str(tmp_path / "lake")))
    generate_ldbc(store, scale_factor=0.004, n_files=3, row_group_rows=512)
    s = connect(ObjectStore(StoreConfig(root=store.config.root)),
                ldbc_graph_schema(), shards=2, shard_block_bits=BLOCK_BITS)
    install_bi_queries(s)
    solo = connect(ObjectStore(StoreConfig(root=store.config.root)),
                   ldbc_graph_schema())
    install_bi_queries(solo)
    try:
        e1 = s.engine.current_epoch().epoch_id
        expected = {e1: solo.query("bi3", min_len=50)}

        results, errors = [], []

        def pound():
            try:
                for _ in range(12):
                    results.append(s.query("bi3", min_len=50))
            except Exception as e:      # pragma: no cover - diagnostics
                errors.append(e)

        threads = [threading.Thread(target=pound) for _ in range(3)]
        for t in threads:
            t.start()
        _stage_append(store, s.engine, n_new=48, seed=5)
        rep = s.engine.advance()
        assert rep.to_epoch > e1
        for t in threads:
            t.join()
        assert not errors, errors

        solo.engine.advance()
        expected[rep.to_epoch] = solo.query("bi3", min_len=50)

        seen = sorted({r.epoch_id for r in results})
        assert seen and set(seen) <= set(expected)
        for r in results:
            # bit-consistent with exactly the epoch it pinned: a torn shard
            # view (one worker pre-, one post-advance) could match neither
            assert_parity(r, expected[r.epoch_id], f"epoch={r.epoch_id}")
        # drained: both engines fresh again, still bit-identical
        assert_parity(solo.query("bi3", min_len=50),
                      s.query("bi3", min_len=50), "drained")
    finally:
        solo.close()
        s.close()


# ---------------------------------------------------------------- retirement


def test_retirement_clears_shard_state(tmp_path):
    store = ObjectStore(StoreConfig(root=str(tmp_path / "lake")))
    generate_ldbc(store, scale_factor=0.004, n_files=2, row_group_rows=512)
    s = connect(ObjectStore(StoreConfig(root=store.config.root)),
                ldbc_graph_schema(), shards=2, shard_block_bits=BLOCK_BITS)
    install_bi_queries(s)
    fab = s.engine._shard_fabric
    try:
        fe1 = fab.current()
        old_epoch_id = fe1.base.epoch_id
        s.query("bi4", city="city_1")
        _stage_append(store, s.engine, n_new=16, seed=7)
        s.engine.advance()
        # the un-referenced old fabric epoch is retired on publish
        assert fe1.retired_fabric
        assert fe1.views == {}
        for w in fab.workers.values():
            assert old_epoch_id not in w.delta_buffers
        assert fab.stats["retired_fabric_epochs"] >= 1
        # the new fabric epoch serves queries
        assert fab.current().base.epoch_id > old_epoch_id
        s.query("bi4", city="city_1")
    finally:
        s.close()


def test_disconnect_mid_advance_clears_and_reshards(tmp_path):
    """Satellite 3: a shard worker disconnect clears its delta buffers and
    the epoch's armed lookup plans, re-shards ownership over the survivors,
    and the fabric keeps serving bit-identical results."""
    store = ObjectStore(StoreConfig(root=str(tmp_path / "lake")))
    generate_ldbc(store, scale_factor=0.004, n_files=2, row_group_rows=512)
    s = connect(ObjectStore(StoreConfig(root=store.config.root)),
                ldbc_graph_schema(), shards=3, shard_block_bits=BLOCK_BITS)
    install_bi_queries(s)
    s.install("person_by_id", "SELECT p FROM Person:p WHERE p.id == $id")
    solo = connect(ObjectStore(StoreConfig(root=store.config.root)),
                   ldbc_graph_schema())
    install_bi_queries(solo)
    fab = s.engine._shard_fabric
    try:
        pid = int(s.engine.topology.idm.raw_ids("Person")[0])
        s.lookup("person_by_id", id=pid)    # arm a lookup plan on the epoch
        base = fab.current().base
        # park some routed delta state on the doomed worker — and on a
        # survivor: the disconnect republishes a new fabric epoch over the
        # SAME base, so retiring the superseded one must not clear delta
        # state keyed by the still-current epoch id
        fab.workers[1].delta_buffers[base.epoch_id] = ["vertex/x.col"]
        fab.workers[0].delta_buffers[base.epoch_id] = ["vertex/y.col"]
        ver = fab.smap.version
        fab.disconnect_worker(1)
        assert fab.smap.live == (0, 2)
        assert fab.smap.version == ver + 1
        assert not fab.workers[1].alive
        assert fab.workers[1].delta_buffers == {}
        assert fab.workers[0].delta_buffers[base.epoch_id] == ["vertex/y.col"]
        assert base.lookup_plans == {}      # armed plans dropped (no leaks)
        assert fab.stats["disconnects"] == 1
        # survivors still produce bit-identical results
        for name in ("bi3", "bi5"):
            assert_parity(solo.query(name, **BI_PARAMS[name]),
                          s.query(name, **BI_PARAMS[name]), name)
        # the last live worker cannot disconnect
        fab.disconnect_worker(0)
        with pytest.raises(RuntimeError):
            fab.disconnect_worker(2)
    finally:
        solo.close()
        s.close()


def test_heartbeat_lapse_reaps_worker(tmp_path):
    """Failure detection drives membership: a worker whose heartbeat lapses
    past the registry timeout is disconnected by reap_dead_workers(), and
    the survivors keep serving bit-identical results."""
    store = ObjectStore(StoreConfig(root=str(tmp_path / "lake")))
    generate_ldbc(store, scale_factor=0.004, n_files=2, row_group_rows=512)
    s = connect(ObjectStore(StoreConfig(root=store.config.root)),
                ldbc_graph_schema(), shards=3, shard_block_bits=BLOCK_BITS)
    install_bi_queries(s)
    solo = connect(ObjectStore(StoreConfig(root=store.config.root)),
                   ldbc_graph_schema())
    install_bi_queries(solo)
    fab = s.engine._shard_fabric
    try:
        expected = solo.query("bi3", **BI_PARAMS["bi3"])
        assert_parity(expected, s.query("bi3", **BI_PARAMS["bi3"]), "warm")
        assert fab.stats_snapshot()["heartbeats_healthy"]
        # age shard-1's heartbeat past the timeout; fresh ticks from the
        # query above keep the others alive
        fab.heartbeats.timeout_s = 60.0
        with fab.heartbeats._lock:
            fab.heartbeats._last["shard-1"] -= 120.0
        assert fab.reap_dead_workers() == [1]
        assert fab.smap.live == (0, 2)
        assert not fab.workers[1].alive
        assert not fab.stats_snapshot()["heartbeats_healthy"]
        assert_parity(expected, s.query("bi3", **BI_PARAMS["bi3"]), "reaped")
        assert fab.reap_dead_workers() == []   # idempotent: already dead
    finally:
        solo.close()
        s.close()


def test_reap_skips_idle_fabric(tmp_path):
    """Regression: heartbeats are ticked only by scan legs, so on an idle
    fabric every heartbeat lapses together — that is idleness, not failure,
    and reap must refresh instead of permanently disconnecting every
    healthy worker but one."""
    store = ObjectStore(StoreConfig(root=str(tmp_path / "lake")))
    generate_ldbc(store, scale_factor=0.004, n_files=2, row_group_rows=512)
    s = connect(ObjectStore(StoreConfig(root=store.config.root)),
                ldbc_graph_schema(), shards=3, shard_block_bits=BLOCK_BITS)
    fab = s.engine._shard_fabric
    try:
        fab.heartbeats.timeout_s = 60.0
        with fab.heartbeats._lock:
            for k in fab.heartbeats._last:
                fab.heartbeats._last[k] -= 120.0   # everyone looks lapsed
        assert fab.reap_dead_workers() == []       # no scans since: idle
        assert fab.smap.live == (0, 1, 2)
        assert all(w.alive for w in fab.workers.values())
        assert fab.stats_snapshot()["heartbeats_healthy"]   # refreshed
        assert fab.stats["disconnects"] == 0
        # burst-then-gap: scans DID run since the last check, but every
        # live heartbeat lapsed together afterwards — still an idle gap
        # (no fresh peer attests a failure), still no reap
        install_bi_queries(s)
        s.query("bi3", min_len=50)
        with fab.heartbeats._lock:
            for k in fab.heartbeats._last:
                fab.heartbeats._last[k] -= 120.0
        assert fab.reap_dead_workers() == []
        assert fab.smap.live == (0, 1, 2)
        assert fab.stats["disconnects"] == 0
    finally:
        s.close()


def test_disconnect_reslices_persisted_csr_blobs(tmp_path):
    """Regression (high): per-shard CSR blob keys are content-addressed by
    the ownership map's slice token, so the republish after a disconnect
    re-slices for the survivor map instead of reusing pre-disconnect blobs
    whose adjacency is zeroed for the blocks reassigned from the dead
    shard (silently dropped edges)."""
    store = ObjectStore(StoreConfig(root=str(tmp_path / "lake")))
    generate_ldbc(store, scale_factor=0.004, n_files=2, row_group_rows=512)
    s = connect(ObjectStore(StoreConfig(root=store.config.root)),
                ldbc_graph_schema(), shards=3, shard_block_bits=BLOCK_BITS)
    install_bi_queries(s)
    solo = connect(ObjectStore(StoreConfig(root=store.config.root)),
                   ldbc_graph_schema())
    install_bi_queries(solo)
    fab = s.engine._shard_fabric
    try:
        # the trigger arm: blobs persisted under the pre-disconnect map
        assert fab._persist
        assert fab.stats["shard_csr_blobs"] > 0
        expected = solo.query("bi5", **BI_PARAMS["bi5"])
        assert_parity(expected, s.query("bi5", **BI_PARAMS["bi5"]), "pre")
        fab.disconnect_worker(1)
        # survivor slices partition the full adjacency: every forward edge
        # of every built CSR belongs to exactly one live shard's slice
        fe = fab.current()
        for ename, full in fe.base.plane.built_csrs().items():
            total = sum(
                len(fe.views[sid].plane.built_csrs()[ename].fwd_dst)
                for sid in fab.smap.live)
            assert total == len(full.fwd_dst), ename
        assert_parity(expected, s.query("bi5", **BI_PARAMS["bi5"]), "post")
    finally:
        solo.close()
        s.close()


def test_close_defers_retirement_until_refs_drain(tmp_path):
    """Regression: close() with a pinned in-flight fabric epoch must not
    retire it out from under the reader (dropping the fabric's base-epoch
    ref); the reader's release() retires it exactly once, and a stray
    double release never double-drops the base ref."""
    store = ObjectStore(StoreConfig(root=str(tmp_path / "lake")))
    generate_ldbc(store, scale_factor=0.004, n_files=2, row_group_rows=512)
    s = connect(ObjectStore(StoreConfig(root=store.config.root)),
                ldbc_graph_schema(), shards=2, shard_block_bits=BLOCK_BITS)
    fab = s.engine._shard_fabric
    try:
        fe = fab.acquire()                    # an in-flight query's pin
        base_refs = fe.base.refs()
        fab.close()
        assert not fe.retired_fabric          # deferred: reader still pinned
        assert fe.base.refs() == base_refs    # fabric's base ref still held
        retired_n = fab.stats["retired_fabric_epochs"]
        fab.release(fe)                       # reader drains -> retires once
        assert fe.retired_fabric
        assert fe.base.refs() == base_refs - 1
        assert fab.stats["retired_fabric_epochs"] == retired_n + 1
        fab.release(fe)                       # stray release: no double drop
        assert fe.base.refs() == base_refs - 1
        assert fab.stats["retired_fabric_epochs"] == retired_n + 1
    finally:
        s.close()


# ---------------------------------------------------------------- ingest


def test_dangling_edge_admission(tmp_path):
    """Satellite 1: an edge upsert whose endpoint vertex is absent is shed
    with the typed DanglingEdgeError; endpoints that are committed, pending,
    or admitted earlier in the same burst are accepted."""
    from repro.ingest.pipeline import IngestConfig, IngestPipeline

    store = ObjectStore(StoreConfig(root=str(tmp_path / "lake")))
    generate_ldbc(store, scale_factor=0.004, n_files=2, row_group_rows=512)
    s = connect(ObjectStore(StoreConfig(root=store.config.root)),
                ldbc_graph_schema())
    pipe = IngestPipeline(s.engine, IngestConfig(auto_advance=False)).start()
    try:
        person = int(s.engine.topology.idm.raw_ids("Person")[0])
        known_cid = int(s.engine.topology.idm.raw_ids("Comment")[0])
        new_cid = int(s.engine.topology.idm.raw_ids("Comment").max()) + 12345

        # absent endpoint -> typed reject with table/column/key attached
        with pytest.raises(DanglingEdgeError) as ei:
            pipe.upsert("Comment_HasCreator_Person",
                        {"src": 10 ** 15, "dst": person,
                         "creationDate": 20230101})
        assert ei.value.table == "Comment_HasCreator_Person"
        assert ei.value.column == "src"
        assert ei.value.key == (10 ** 15,)

        # committed endpoint -> admitted
        pipe.upsert("Comment_HasCreator_Person",
                    {"src": known_cid, "dst": person,
                     "creationDate": 20230101})

        # vertex-then-edge in one burst: the vertex may still sit in the
        # bounded queue (not drained), yet the edge must be admitted
        pipe.upsert("Comment", {"id": new_cid, "creationDate": 20230101,
                                "length": 7, "browserUsed": "Chrome"})
        pipe.upsert("Comment_HasCreator_Person",
                    {"src": new_cid, "dst": person,
                     "creationDate": 20230101})

        # delete-then-edge: the endpoint *has existed* (committed in the
        # lake), so the edge is admitted — last-write-wins ordering is the
        # stream's business, and a batch replay of the same history produces
        # the same dangling row.  Only never-existed endpoints reject.
        pipe.delete("Comment", (known_cid,))
        pipe.upsert("Comment_HasCreator_Person",
                    {"src": known_cid, "dst": person,
                     "creationDate": 20230102})

        # a second never-existed endpoint still sheds
        with pytest.raises(DanglingEdgeError):
            pipe.upsert("Comment_HasCreator_Person",
                        {"src": 10 ** 15 + 1, "dst": person,
                         "creationDate": 20230103})
        assert pipe.committer.snapshot_counters()[
            "dangling_edges_rejected"] == 2
    finally:
        pipe.close()
        s.close()


# ---------------------------------------------------------------- serving


def test_server_wire_surface(lake, sharded):
    from repro.serving.server import QueryServer, ServerConfig

    srv = QueryServer(sharded, config=ServerConfig(refresh_interval_s=0))
    try:
        sharded.install("person_by_id",
                        "SELECT p FROM Person:p WHERE p.id == $id") \
            if not sharded.is_installed("person_by_id") else None
        pid = 11    # the generator's raw-id scheme: person k -> k*10 + 1

        r = srv.handle("GET", f"/vertex/Person/{pid}",
                       {"columns": ["gender"]})
        assert r["status"] == 200 and "gender" in r["value"]
        assert srv.handle("GET", "/vertex/Person/987654321")["status"] == 404

        r = srv.handle("GET", f"/neighbors/Knows/{pid}")
        assert r["status"] == 200 and r["value"]["n"] == len(
            r["value"]["neighbors"])

        r = srv.handle("POST", "/query/bi1",
                       {"tag": "Music", "date": 20100101})
        assert r["status"] == 200 and r["value"].ok

        r = srv.handle("GET", "/lookup/person_by_id", {"id": pid})
        assert r["status"] == 200 and r["value"].value.tier == "green"

        assert srv.handle("GET", "/no/such/route")["status"] == 404
        assert srv.handle("DELETE", "/health")["status"] == 405

        h = srv.handle("GET", "/health")
        assert h["status"] == 200
        health = h["value"]
        assert health["routes"]["/vertex"] == 2
        assert health["routes"]["/query"] == 1
        assert health["routes"]["errors"] == 3
        # fabric section: shard health rides the same snapshot
        assert health["fabric"]["n_shards"] == 4
        assert health["fabric"]["live_shards"] == [0, 1, 2, 3]
        assert health["stats"]["lookup_requests"] >= 1
    finally:
        srv.close()
