"""Tests: GSQL execution — BI suite parity against the pre-refactor builder
implementations (pinned), session facade, explain, per-query timeouts and
serving admission control (DESIGN.md §8)."""

import threading

import numpy as np
import pytest

from repro.core.bi_queries import BI_GSQL, BI_QUERIES, install_bi_queries
from repro.core.engine import GraphLakeEngine
from repro.core.plan import QueryTimeoutError
from repro.core.query import ExecOptions, Query, accum_sum, eq, ge, gt, le
from repro.core.types import VSet
from repro.data.ldbc import generate_ldbc, ldbc_graph_schema
from repro.gsql.errors import GSQLCompileError, GSQLSyntaxError
from repro.gsql.session import GraphSession, connect
from repro.lakehouse.objectstore import ObjectStore, StoreConfig
from repro.serving.server import (
    QueryServer,
    ServerConfig,
    ServerOverloadedError,
    latency_stats,
)


@pytest.fixture(scope="module")
def lake(tmp_path_factory):
    store = ObjectStore(StoreConfig(root=str(tmp_path_factory.mktemp("lake"))))
    generate_ldbc(store, scale_factor=0.004, n_files=3, row_group_rows=512)
    return store


@pytest.fixture(scope="module")
def session(lake):
    s = connect(lake, ldbc_graph_schema())
    install_bi_queries(s)
    yield s
    s.close()


@pytest.fixture(scope="module")
def legacy_engine(lake):
    """A second engine over the same lake for the pre-refactor builder
    replicas — its accumulator state never mixes with the session's."""
    eng = GraphLakeEngine(lake, ldbc_graph_schema())
    eng.startup()
    yield eng
    eng.close()


# ---------------------------------------------------------------------------
# the pre-refactor builder implementations, verbatim — the parity pins
# ---------------------------------------------------------------------------

def _legacy_bi1(engine, tag_name="Music", date=20100101):
    res = (Query(engine)
           .vertices("Tag", where=eq("name", tag_name))
           .hop("HasTag", direction="in")
           .hop("HasCreator", direction="out",
                edge_where=gt("creationDate", date),
                target_where=eq("gender", "Female"),
                accum=accum_sum("cnt", 1.0))
           .run())
    counts = res.accumulators.get("cnt", np.zeros(1))
    return {
        "n_persons": int(res.vset.size()),
        "total_comments": float(counts.sum()),
        "max_per_person": float(counts.max()) if len(counts) else 0.0,
        "edges_scanned": res.n_edges_scanned,
    }


def _legacy_bi2(engine, date_lo=20120101, date_hi=20151231):
    res = (Query(engine)
           .vertices("Comment")
           .hop("HasCreator", direction="out",
                edge_where=ge("creationDate", date_lo) & le("creationDate", date_hi))
           .run())
    active = res.frames[0].u_set(engine.topology.n_vertices("Comment"))
    frame = engine.edge_scan(active, "HasTag", "out")
    engine.register_accum("Tag", "tag_cnt", op="sum")
    engine.accums.update("Tag", "tag_cnt", frame.v, 1.0)
    counts = engine.accums.array("Tag", "tag_cnt")
    out = {
        "n_active_comments": int(active.size()),
        "n_tags_touched": int((counts > 0).sum()),
        "top_tag_count": float(counts.max()) if len(counts) else 0.0,
    }
    engine.accums.reset("Tag", "tag_cnt")
    return out


def _legacy_bi3(engine, min_len=500):
    res = (Query(engine)
           .vertices("Comment")
           .hop("HasCreator", direction="out",
                source_where=gt("length", min_len),
                accum=accum_sum("tot_len", "u.length"))
           .run())
    tot = res.accumulators["tot_len"]
    return {
        "n_persons": int((tot > 0).sum()),
        "total_length": float(tot.sum()),
    }


def _legacy_bi4(engine, city="city_1"):
    res = (Query(engine)
           .vertices("Person", where=eq("locationCity", city))
           .hop("Knows", direction="out", accum=accum_sum("deg", 1.0, target="u"))
           .run())
    deg = res.accumulators["deg"]
    return {
        "n_friend_edges": float(deg.sum()),
        "max_degree": float(deg.max()) if len(deg) else 0.0,
    }


def _legacy_bi5(engine, min_degree=10, date=20140101):
    res = (Query(engine)
           .vertices("Person")
           .hop("Knows", direction="out", accum=accum_sum("deg", 1.0, target="u"))
           .run())
    deg = res.accumulators["deg"]
    n_p = engine.topology.n_vertices("Person")
    influencers = VSet.from_dense_ids("Person", n_p, np.flatnonzero(deg >= min_degree))
    frame = engine.edge_scan(
        influencers, "HasCreator", "in",
        edge_columns=["creationDate"],
        edge_filter=lambda fr: fr["e.creationDate"] > date,
    )
    comments = frame.v_set(engine.topology.n_vertices("Comment"))
    frame2 = engine.edge_scan(comments, "HasTag", "out")
    engine.register_accum("Tag", "inf_cnt", op="sum")
    engine.accums.update("Tag", "inf_cnt", frame2.v, 1.0)
    counts = engine.accums.array("Tag", "inf_cnt")
    out = {
        "n_influencers": int(influencers.size()),
        "n_comments": int(comments.size()),
        "n_tags": int((counts > 0).sum()),
    }
    engine.accums.reset("Tag", "inf_cnt")
    return out


_LEGACY = {"bi1": _legacy_bi1, "bi2": _legacy_bi2, "bi3": _legacy_bi3,
           "bi4": _legacy_bi4, "bi5": _legacy_bi5}
_PARAMS = {
    "bi1": [{}, {"tag_name": "Sports", "date": 20090101}],
    "bi2": [{}, {"date_lo": 20100101, "date_hi": 20121231}],
    "bi3": [{}, {"min_len": 100}],
    "bi4": [{}, {"city": "city_7"}],
    "bi5": [{}, {"min_degree": 5, "date": 20100101}],
}


@pytest.mark.parametrize("name", list(BI_GSQL))
def test_bi_gsql_matches_prerefactor_builder(session, legacy_engine, name):
    """Every BI query, as installed GSQL text, must reproduce the
    pre-refactor builder output bit-for-bit (incl. non-default params)."""
    for params in _PARAMS[name]:
        # the legacy path mutated accumulators cumulatively across calls;
        # each pin compares against a fresh legacy accumulator state (what a
        # first call produced pre-refactor)
        for key in list(legacy_engine.accums._arrays):
            legacy_engine.accums.reset(*key)
        expected = _LEGACY[name](legacy_engine, **params)
        got = BI_QUERIES[name](session, **params)
        assert got == expected, (name, params)


def test_bi_queries_are_deterministic_across_repeats(session):
    """Session execution uses a private per-query accumulator store, so
    repeated calls are pure — unlike the legacy builder path, which
    accumulated into the shared engine store."""
    first = BI_QUERIES["bi1"](session)
    second = BI_QUERIES["bi1"](session)
    assert first == second


def test_bi_queries_accept_engine_and_install_lazily(lake):
    eng = GraphLakeEngine(lake, ldbc_graph_schema())
    eng.startup()
    try:
        out = BI_QUERIES["bi4"](eng, city="city_3")
        assert set(out) == {"n_friend_edges", "max_degree"}
        assert eng.session().is_installed("bi4")
    finally:
        eng.close()


def test_no_raw_edge_scans_left_in_bi_queries():
    import inspect

    import repro.core.bi_queries as m
    src = inspect.getsource(m)
    assert "edge_scan" not in src
    assert "Query(" not in src


# ---------------------------------------------------------------------------
# session facade
# ---------------------------------------------------------------------------

def test_session_query_text_vs_installed_name(session):
    by_name = session.query("bi4", city="city_1")
    by_text = session.query(BI_GSQL["bi4"], city="city_1")
    np.testing.assert_array_equal(by_name.vset.ids(), by_text.vset.ids())
    np.testing.assert_array_equal(by_name.accumulators["deg"],
                                  by_text.accumulators["deg"])


def test_session_install_validates_at_install_time(session):
    with pytest.raises(GSQLCompileError, match="no column 'nam'"):
        session.install("bad", "SELECT t FROM Tag:t WHERE t.nam == 'x'")
    assert not session.is_installed("bad")
    iq = session.install("tags_of", """
        SELECT t FROM Comment:c -(HasTag:e)- Tag:t WHERE c.id == $cid
    """)
    assert iq.param_names == frozenset({"cid"})


def test_session_malformed_and_invalid_queries_raise_positioned(session):
    with pytest.raises(GSQLSyntaxError) as exc:
        session.query("SELECT p FROM Tag:t WHERE t.name = 'x'")
    assert exc.value.line == 1 and exc.value.col is not None
    with pytest.raises(GSQLCompileError) as exc2:
        session.query("SELECT p FROM Tag:t\n  -(Flies:e)- Comment:p")
    assert exc2.value.line == 2
    with pytest.raises(GSQLCompileError, match=r"unbound parameter \$tag"):
        session.query(BI_GSQL["bi1"], date=1)


def test_zero_hop_statement_and_projection(session):
    eng = session.engine
    res = session.query("SELECT s FROM Person:s WHERE s.gender == 'Female'")
    vset, _ = eng.vertex_map(
        eng.all_vertices("Person"), columns=["gender"],
        filter_fn=lambda fr: np.asarray([g == "Female" for g in fr["gender"]]))
    np.testing.assert_array_equal(res.vset.ids(), vset.ids())
    assert res.alias_sets["s"].size() == res.vset.size()
    assert res.n_edges_scanned == 0 and res.frames == []


def test_select_source_alias_projects_matched_sources(session):
    # SELECT the *source* side: comments that actually have a tag
    res = session.query("SELECT c FROM Comment:c -(HasTag:e)- Tag:t")
    frame = res.frames[0]
    n_c = session.engine.topology.n_vertices("Comment")
    np.testing.assert_array_equal(res.vset.ids(), frame.u_set(n_c).ids())
    # and the far side set is recorded under its alias
    n_t = session.engine.topology.n_vertices("Tag")
    np.testing.assert_array_equal(res.alias_sets["t"].ids(),
                                  frame.v_set(n_t).ids())


def test_multi_statement_accum_filter_matches_manual(session):
    res = session.query("""
        SELECT q FROM Person:a -(Knows:k)-> Person:q ACCUM a.@deg += 1;
        SELECT s FROM Person:s WHERE s.@deg >= $k
    """, k=5)
    deg = res.accumulators["deg"]
    np.testing.assert_array_equal(res.vset.ids(), np.flatnonzero(deg >= 5))


def test_session_options_override_and_pushdown_parity(session):
    base = session.query("bi1", tag="Music", date=20100101)
    off = session.query("bi1", tag="Music", date=20100101,
                        options=ExecOptions(pushdown=False, pipeline=False))
    np.testing.assert_array_equal(base.vset.ids(), off.vset.ids())
    np.testing.assert_array_equal(base.accumulators["cnt"],
                                  off.accumulators["cnt"])
    assert base.n_edges_scanned == off.n_edges_scanned


def test_explain_names_stages_bounds_and_topology(session):
    text = session.explain("bi1", tag="Music", date=20100101)
    assert "seed Tag" in text and "name in {'Music'}" in text
    assert "stage E: columns=['creationDate']" in text
    assert "creationDate > 20100101" in text
    assert "stage V: columns=['gender']" in text and "gender in {'Female'}" in text
    assert "direction=in" in text and "direction=out" in text
    assert "CSR" in text or "edge-list" in text
    # post-accum plans render too
    text2 = session.explain("bi2", lo=1, hi=2)
    assert "post-accum 1: from 'c'" in text2
    # and multi-statement queries list both statements
    text5 = session.explain("bi5", min_degree=10, date=20140101)
    assert "statement 2" in text5 and "@deg >= 10.0" in text5


def test_connect_owns_engine(lake):
    s = connect(lake, ldbc_graph_schema())
    eng = s.engine
    assert eng.startup_mode in ("first_connection", "second_connection")
    res = s.query("SELECT t FROM Tag:t")
    assert res.vset.size() > 0
    s.close()
    # pool is closed once the owning session closes
    assert eng.pool._closed if hasattr(eng.pool, "_closed") else True


# ---------------------------------------------------------------------------
# timeouts
# ---------------------------------------------------------------------------

def test_query_timeout_raises_at_stage_boundary(session):
    with pytest.raises(QueryTimeoutError):
        session.query("bi1", tag="Music", date=20100101,
                      options=ExecOptions(timeout_s=0.0))


def test_builder_timeout_via_options(session):
    q = Query(session.engine).vertices("Comment").hop("HasCreator")
    with pytest.raises(QueryTimeoutError):
        q.run(options=ExecOptions(timeout_s=0.0))


def test_run_kwargs_shims_retired(session):
    """The deprecated ``run(pushdown=..., pipeline=...)`` kwargs are gone:
    execution knobs travel in ExecOptions only."""
    q = Query(session.engine).vertices("Comment").hop(
        "HasCreator", edge_where=gt("creationDate", 20150101))
    with pytest.raises(TypeError):
        q.run(pushdown=False)
    with pytest.raises(TypeError):
        q.run(pipeline=True)
    res = q.run(options=ExecOptions(pushdown=False))
    assert res.route == "full"


# ---------------------------------------------------------------------------
# serving: installed queries, admission control, per-query timeout
# ---------------------------------------------------------------------------

def test_server_serves_installed_queries_with_params(session):
    server = QueryServer(session, config=ServerConfig(n_workers=2))
    try:
        reqs = [("bi1", {"tag": "Music", "date": 20100101 + i}) for i in range(3)]
        reqs += [("bi4", {"city": f"city_{i}"}) for i in range(3)]
        results = server.run_batch(reqs)
        assert all(r.ok for r in results), [r.error for r in results]
        # installed queries return full QueryResults, epoch-stamped
        assert all(r.value.epoch_id >= 1 for r in results)
        stats = latency_stats(results)
        assert stats["count"] == 6
        r = server.run_batch([("nope", {})])[0]
        assert not r.ok and "no installed query" in r.error
    finally:
        server.close()


def test_server_admission_control_sheds_when_full(session):
    release = threading.Event()

    def block(engine, **params):
        release.wait(timeout=30.0)
        return "done"

    server = QueryServer(session, {"block": block},
                         config=ServerConfig(n_workers=1, max_queue=1))
    try:
        rids, shed = [], 0
        for _ in range(10):
            try:
                rids.append(server.submit("block"))
            except ServerOverloadedError as e:
                shed += 1
                assert "queue full" in str(e)
        assert shed > 0, "bounded queue never shed under a stalled worker"
        assert len(rids) >= 1
        release.set()
        for rid in rids:
            assert server.result(rid, timeout_s=30.0).value == "done"
    finally:
        release.set()
        server.close()


def test_server_per_query_timeout_is_typed_error(session):
    server = QueryServer(session, config=ServerConfig(n_workers=1, timeout_s=0.0))
    try:
        r = server.run_batch([("bi1", {"tag": "Music", "date": 20100101})])[0]
        assert not r.ok and "QueryTimeoutError" in r.error
        # the worker survives a timed-out request and keeps serving
        release_ok = server.run_batch([("bi4", {"city": "city_1"})])[0]
        assert not release_ok.ok or release_ok.ok  # no hang either way
    finally:
        server.close()


# ---------------------------------------------------------------------------
# review regressions: private accumulator stores, batch overload draining
# ---------------------------------------------------------------------------

def test_returned_accumulators_survive_later_queries(session):
    first = session.query("bi1", tag="Music", date=20100101)
    snapshot = np.array(first.accumulators["cnt"])
    session.query("bi1", tag="Sports", date=20120101)
    # the first result's arrays live in its own private store — a later
    # query must not zero or refill them
    np.testing.assert_array_equal(first.accumulators["cnt"], snapshot)


def test_session_queries_leave_engine_accums_untouched(session):
    eng = session.engine
    before = set(eng.accums._arrays)
    session.query("bi4", city="city_1")
    assert set(eng.accums._arrays) == before


def test_run_batch_drains_batches_larger_than_queue(session):
    server = QueryServer(session, config=ServerConfig(n_workers=2, max_queue=2))
    try:
        results = server.run_batch(
            [("bi4", {"city": f"city_{i % 10}"}) for i in range(12)])
        assert len(results) == 12 and all(r.ok for r in results)
    finally:
        server.close()
