"""Tests: shared-scan batch execution (DESIGN.md §9) and the batching
server — parity, scheduler grouping, priority lanes, tenant quotas,
queue-time accounting, TTL eviction, and perf-flag hygiene."""

import threading
import time
import warnings

import numpy as np
import pytest

from repro.core.engine import GraphLakeEngine
from repro.data.ldbc import generate_ldbc, ldbc_graph_schema
from repro.gsql.session import GraphSession
from repro.lakehouse.objectstore import ObjectStore, StoreConfig
from repro.serving.server import (
    QueryServer,
    ServerConfig,
    ServerOverloadedError,
    TenantQuotaExceededError,
    latency_stats,
)

HOT = """
    SELECT p FROM Comment:c -(HasCreator:e)- Person:p
    WHERE e.creationDate > $thr
    ACCUM p.@cnt += 1
"""
TWO_HOP = """
    SELECT p FROM Tag:t -(HasTag:e1)- Comment:c -(HasCreator:e2)- Person:p
    WHERE t.name == $tag AND e2.creationDate > $date
    ACCUM p.@deg += 1
"""


@pytest.fixture(scope="module")
def engine(tmp_path_factory):
    store = ObjectStore(StoreConfig(root=str(tmp_path_factory.mktemp("lake"))))
    generate_ldbc(store, scale_factor=0.004, n_files=3, row_group_rows=512)
    eng = GraphLakeEngine(store, ldbc_graph_schema())
    eng.startup()
    yield eng
    eng.close()


@pytest.fixture(scope="module")
def session(engine):
    s = GraphSession.for_engine(engine)
    s.install("hot", HOT)
    s.install("two_hop", TWO_HOP)
    return s


def _assert_identical(a, b):
    assert np.array_equal(a.vset.ids(), b.vset.ids())
    assert a.n_edges_scanned == b.n_edges_scanned
    for fa, fb in zip(a.frames, b.frames):
        assert np.array_equal(fa.u, fb.u) and np.array_equal(fa.v, fb.v)
        assert set(fa.columns) == set(fb.columns)
        for k in fa.columns:
            assert np.array_equal(fa.columns[k], fb.columns[k]), k
    assert set(a.accumulators) == set(b.accumulators)
    for k in a.accumulators:
        assert np.array_equal(a.accumulators[k], b.accumulators[k]), k


# ---------------------------------------------------------------------------
# query_batch parity
# ---------------------------------------------------------------------------

def test_query_batch_bit_parity_varied_params(session):
    params = [{"thr": 20090101 + i * 5000} for i in range(5)]
    batched = session.query_batch("hot", params)
    for p, res in zip(params, batched):
        _assert_identical(res, session.query("hot", **p))


def test_query_batch_two_hop_parity(session):
    params = [{"tag": "Music", "date": 20090101},
              {"tag": "Music", "date": 20110101}]
    batched = session.query_batch("two_hop", params)
    for p, res in zip(params, batched):
        _assert_identical(res, session.query("two_hop", **p))


def test_query_batch_single_rider_matches_solo(session):
    [res] = session.query_batch("hot", [{"thr": 20100101}])
    _assert_identical(res, session.query("hot", thr=20100101))


def test_query_batch_shared_pass_counters(session):
    """Same-parameter riders: the shared pass reads one solo run's worth of
    chunks, and every rider reports the shared pass's counters."""
    eng = session.engine
    eng.cache.drop_all()
    solo = session.query("hot", thr=20100101)
    eng.cache.drop_all()
    riders = session.query_batch("hot", [{"thr": 20100101}] * 6)
    assert riders[0].pruning["chunks_read"] == solo.pruning["chunks_read"]
    for r in riders[1:]:
        assert r.pruning == riders[0].pruning


def test_query_batch_mixed_shapes_rejected(session):
    with pytest.raises(ValueError, match="one query template"):
        from repro.core.query import execute_compiled_batch
        compiled = [session._compile("hot", {"thr": 1}),
                    session._compile("two_hop",
                                     {"tag": "Music", "date": 20100101})]
        execute_compiled_batch(session.engine, compiled)


# ---------------------------------------------------------------------------
# server: batch scheduler
# ---------------------------------------------------------------------------

def test_server_forms_batches(session):
    srv = QueryServer(session, config=ServerConfig(
        n_workers=2, batch_window_ms=20.0))
    try:
        rids = [srv.submit("hot", thr=20090101 + i * 1000) for i in range(8)]
        results = [srv.result(r) for r in rids]
        assert all(r.ok for r in results), [r.error for r in results]
        assert srv.stats["batches"] >= 1
        assert srv.stats["batched_requests"] >= 2
        assert srv.stats["max_batch_riders"] >= 2
        solo = session.query("hot", thr=20090101)
        _assert_identical(results[0].value, solo)
    finally:
        srv.close()


def test_server_batching_off_is_per_request(session):
    srv = QueryServer(session, config=ServerConfig(
        n_workers=2, batch_window_ms=0.0))
    try:
        rids = [srv.submit("hot", thr=20090101 + i * 1000) for i in range(4)]
        assert all(srv.result(r).ok for r in rids)
        assert srv.stats["batches"] == 0
        assert srv.stats["solo_requests"] == 4
    finally:
        srv.close()


def test_server_max_batch_riders_caps_group(session):
    srv = QueryServer(session, config=ServerConfig(
        n_workers=1, batch_window_ms=50.0, max_batch_riders=3))
    try:
        rids = [srv.submit("hot", thr=20090101 + i) for i in range(6)]
        assert all(srv.result(r).ok for r in rids)
        assert srv.stats["max_batch_riders"] <= 3
        assert srv.stats["batches"] >= 2
    finally:
        srv.close()


def test_server_priority_lanes(engine):
    """With one worker pinned on a blocker, a later priority-0 request
    dispatches before the earlier priority-5 one."""
    order = []
    gate = threading.Event()

    def blocker(engine):
        gate.wait(2.0)
        return "unblocked"

    def note(engine, tag):
        order.append(tag)
        return tag

    srv = QueryServer(engine, {"blocker": blocker, "note": note},
                      ServerConfig(n_workers=1, batch_window_ms=0.0))
    try:
        b = srv.submit("blocker")
        time.sleep(0.05)          # ensure the worker picked up the blocker
        lo = srv.submit("note", priority=5, tag="lo")
        hi = srv.submit("note", priority=0, tag="hi")
        time.sleep(0.05)          # let both enqueue before the gate opens
        gate.set()
        assert srv.result(b).value == "unblocked"
        assert srv.result(hi).ok and srv.result(lo).ok
        assert order == ["hi", "lo"]
    finally:
        gate.set()
        srv.close()


# ---------------------------------------------------------------------------
# server: admission control, quotas, accounting
# ---------------------------------------------------------------------------

def test_tenant_quota_typed_shed(engine):
    gate = threading.Event()

    def blocker(engine):
        gate.wait(2.0)
        return "ok"

    srv = QueryServer(engine, {"blocker": blocker},
                      ServerConfig(n_workers=1, batch_window_ms=0.0,
                                   tenant_quota=2))
    try:
        r1 = srv.submit("blocker", tenant="acme")
        r2 = srv.submit("blocker", tenant="acme")
        with pytest.raises(TenantQuotaExceededError):
            srv.submit("blocker", tenant="acme")
        # quota is per tenant: another tenant is admitted
        r3 = srv.submit("blocker", tenant="other")
        assert srv.stats["shed_tenant_quota"] == 1
        gate.set()
        assert all(srv.result(r).ok for r in (r1, r2, r3))
        # completions release the quota: the tenant may submit again
        gate.set()
        r4 = srv.submit("blocker", tenant="acme")
        assert srv.result(r4).ok
    finally:
        gate.set()
        srv.close()


def test_overload_shed_keeps_rid_accounting(engine):
    """ServerOverloadedError must not corrupt request-id accounting: shed
    submissions burn no result slots, later requests complete normally."""
    gate = threading.Event()

    def blocker(engine):
        gate.wait(2.0)
        return "ok"

    srv = QueryServer(engine, {"blocker": blocker},
                      ServerConfig(n_workers=1, max_queue=1,
                                   batch_window_ms=0.0))
    try:
        first = srv.submit("blocker")
        time.sleep(0.05)            # worker holds `first`; queue is empty
        second = srv.submit("blocker")   # fills max_queue=1
        shed = 0
        for _ in range(4):
            try:
                srv.submit("blocker")
            except ServerOverloadedError:
                shed += 1
        assert shed >= 1
        assert srv.stats["shed_queue_full"] == shed
        gate.set()
        res_first, res_second = srv.result(first), srv.result(second)
        assert res_first.ok and res_second.ok
        assert res_first.request_id == first
        assert res_second.request_id == second
        # after the shed storm, the server still serves fresh requests
        again = srv.submit("blocker")
        assert again > second
        assert srv.result(again).ok
        # no abandoned result slots: shed requests never complete
        assert not srv._results and not srv._done_at
    finally:
        gate.set()
        srv.close()


def test_latency_accounting_under_concurrent_load(session):
    srv = QueryServer(session, config=ServerConfig(
        n_workers=2, batch_window_ms=5.0))
    results = []
    res_lock = threading.Lock()

    def client(i):
        rid = srv.submit("hot", thr=20090101 + (i % 4) * 3000)
        r = srv.result(rid)
        with res_lock:
            results.append(r)

    try:
        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(12)]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        assert all(r.ok for r in results)
        assert len({r.request_id for r in results}) == 12
        for r in results:
            assert r.queued_s >= 0.0 and r.service_s > 0.0
            assert r.queued_s + r.service_s <= wall + 0.05
        stats = latency_stats(results)
        assert stats["count"] == 12
        assert stats["p99_s"] >= stats["p50_s"]
        assert stats["mean_queued_s"] >= 0.0
    finally:
        srv.close()


def test_total_timeout_expires_in_queue(engine):
    """A request whose queue wait exhausts total_timeout_s fails without
    executing and is counted in expired_in_queue."""
    gate = threading.Event()
    ran = []

    def blocker(engine):
        gate.wait(2.0)
        return "ok"

    def never(engine):
        ran.append(1)
        return "ran"

    srv = QueryServer(engine, {"blocker": blocker, "never": never},
                      ServerConfig(n_workers=1, batch_window_ms=0.0,
                                   total_timeout_s=0.05))
    try:
        b = srv.submit("blocker")
        time.sleep(0.02)
        doomed = srv.submit("never")
        time.sleep(0.15)            # its budget burns away in the queue
        gate.set()
        assert srv.result(b).ok
        res = srv.result(doomed)
        assert not res.ok and "QueryTimeoutError" in res.error
        assert not ran                      # it never executed
        assert srv.stats["expired_in_queue"] == 1
    finally:
        gate.set()
        srv.close()


# ---------------------------------------------------------------------------
# server: results lifecycle
# ---------------------------------------------------------------------------

def test_result_event_wakes_before_completion_poll(engine):
    """result() called before completion parks on an Event and returns
    promptly once the query finishes (no polling interval quantization)."""
    def quick(engine):
        time.sleep(0.05)
        return 42

    srv = QueryServer(engine, {"quick": quick},
                      ServerConfig(n_workers=1, batch_window_ms=0.0))
    try:
        rid = srv.submit("quick")
        t0 = time.perf_counter()
        res = srv.result(rid, timeout_s=5.0)
        waited = time.perf_counter() - t0
        assert res.ok and res.value == 42
        assert waited < 1.0
    finally:
        srv.close()


def test_result_ttl_eviction_counted(engine):
    def quick(engine):
        return 1

    srv = QueryServer(engine, {"quick": quick},
                      ServerConfig(n_workers=1, batch_window_ms=0.0,
                                   result_ttl_s=0.05))
    try:
        rid = srv.submit("quick")
        deadline = time.monotonic() + 5.0
        while (srv.stats["evicted_results"] == 0
               and time.monotonic() < deadline):
            time.sleep(0.05)      # TTL sweep rides the scheduler heartbeat
        assert srv.stats["evicted_results"] == 1
        assert not srv._results and not srv._done_at
        with pytest.raises(TimeoutError):
            srv.result(rid, timeout_s=0.05)
        # completion (not collection) released the tenant slot
        assert not srv._tenant_inflight
    finally:
        srv.close()


# ---------------------------------------------------------------------------
# perf_flags hygiene
# ---------------------------------------------------------------------------

def test_perf_flags_warn_on_unknown_name(monkeypatch):
    from repro import perf_flags

    monkeypatch.setenv("REPRO_OPTS", "pushdwon,batch")
    perf_flags._checked.discard("pushdwon,batch")
    with pytest.warns(UserWarning, match="pushdwon"):
        assert perf_flags.enabled("batch")
    # warn-once per distinct REPRO_OPTS string
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert not perf_flags.enabled("pushdown")


def test_perf_flags_known_names_silent(monkeypatch):
    from repro import perf_flags

    monkeypatch.setenv("REPRO_OPTS", "batch=5,pushdown")
    perf_flags._checked.discard("batch=5,pushdown")
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert perf_flags.enabled("batch")
        assert perf_flags.value("batch", 2.0) == 5.0
        assert perf_flags.enabled("pushdown")


def test_batch_flag_sets_server_window(session, monkeypatch):
    from repro import perf_flags

    monkeypatch.setenv("REPRO_OPTS", "batch=7")
    perf_flags._checked.add("batch=7")
    srv = QueryServer(session, config=ServerConfig(n_workers=1))
    try:
        assert srv._window_s == pytest.approx(0.007)
    finally:
        srv.close()
    monkeypatch.setenv("REPRO_OPTS", "")
    srv2 = QueryServer(session, config=ServerConfig(n_workers=1))
    try:
        assert srv2._window_s == 0.0
    finally:
        srv2.close()
