"""Tests: predicate-pushdown planner, zone-map chunk pruning, staged late
materialization (DESIGN.md §4).

The load-bearing property is *parity*: `run(pushdown=True)` must produce
bit-identical results to the legacy full-materialization path across
selectivities, predicate placements, compositions that degrade to no-prune,
and columns without statistics.
"""

import numpy as np
import pytest

from repro.core.cache.manager import CacheManager
from repro.core.cache.prefetch import Prefetcher
from repro.core.engine import GraphLakeEngine
from repro.core.plan import ColumnBounds
from repro.core.primitives import read_edge_columns_pruned
from repro.core.query import (
    ExecOptions, Predicate, Query, accum_sum, eq, ge, gt, isin, le, lt, ne,
)
from repro.core.topology import GraphTopology
from repro.core.types import VSet
from repro.data.ldbc import generate_ldbc, ldbc_graph_schema
from repro.lakehouse.objectstore import ObjectStore, StoreConfig
from repro.lakehouse.table import LakeCatalog


@pytest.fixture(scope="module")
def engine(tmp_path_factory):
    store = ObjectStore(StoreConfig(root=str(tmp_path_factory.mktemp("lake"))))
    generate_ldbc(store, scale_factor=0.004, n_files=3, row_group_rows=256)
    eng = GraphLakeEngine(store, ldbc_graph_schema())
    eng.startup()
    yield eng
    eng.close()


def _assert_parity(res_a, res_b):
    assert res_a.n_edges_scanned == res_b.n_edges_scanned
    np.testing.assert_array_equal(res_a.vset.ids(), res_b.vset.ids())
    assert len(res_a.frames) == len(res_b.frames)
    for fa, fb in zip(res_a.frames, res_b.frames):
        np.testing.assert_array_equal(fa.u, fb.u)
        np.testing.assert_array_equal(fa.v, fb.v)
        assert set(fa.columns) == set(fb.columns)
        for k in fa.columns:
            np.testing.assert_array_equal(fa.columns[k], fb.columns[k])
    assert set(res_a.accumulators) == set(res_b.accumulators)
    for k in res_a.accumulators:
        np.testing.assert_array_equal(res_a.accumulators[k], res_b.accumulators[k])


def _run_both(engine, build, accum=None):
    """Run a query builder twice (pushdown off/on) from identical state.

    Accumulator arrays are live references into the engine; snapshot them
    before resetting so the parity check compares real per-run results.
    """
    engine.cache.drop_all()
    res_off = build().run(ExecOptions(pushdown=False))
    res_off.accumulators = {k: v.copy() for k, v in res_off.accumulators.items()}
    if accum is not None:
        engine.accums.reset(*accum)
    engine.cache.drop_all()
    res_on = build().run(ExecOptions(pushdown=True))
    res_on.accumulators = {k: v.copy() for k, v in res_on.accumulators.items()}
    if accum is not None:
        engine.accums.reset(*accum)
    return res_off, res_on


# ---------------------------------------------------------------------------
# parity across selectivities and predicate placement
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("date", [20090101, 20150601, 20200101, 20221001])
def test_parity_edge_predicate_selectivities(engine, date):
    def build():
        return (Query(engine).vertices("Comment")
                .hop("HasCreator", "out", edge_where=gt("creationDate", date)))
    res_off, res_on = _run_both(engine, build)
    _assert_parity(res_off, res_on)


def test_parity_source_predicate(engine):
    def build():
        return (Query(engine).vertices("Comment")
                .hop("HasCreator", "out", source_where=gt("length", 1500)))
    _assert_parity(*_run_both(engine, build))


def test_parity_target_predicate_object_column(engine):
    # object-dtype column: no chunk statistics -> must degrade to no-prune
    def build():
        return (Query(engine).vertices("Comment")
                .hop("HasCreator", "out", target_where=eq("gender", "Female")))
    res_off, res_on = _run_both(engine, build)
    _assert_parity(res_off, res_on)
    assert res_on.vset.size() > 0


def test_parity_all_placements_and_accum(engine):
    def build():
        return (Query(engine).vertices("Comment")
                .hop("HasCreator", "out",
                     edge_where=ge("creationDate", 20120101) & le("creationDate", 20180101),
                     source_where=gt("length", 200),
                     target_where=eq("gender", "Male"),
                     accum=accum_sum("tot_len", "u.length")))
    res_off, res_on = _run_both(engine, build, accum=("Person", "tot_len"))
    _assert_parity(res_off, res_on)
    assert res_on.accumulators["tot_len"].sum() > 0


def test_parity_multi_hop_with_seed_where(engine):
    def build():
        return (Query(engine)
                .vertices("Tag", where=eq("name", "Music"))
                .hop("HasTag", direction="in")
                .hop("HasCreator", direction="out",
                     edge_where=gt("creationDate", 20150101),
                     accum=accum_sum("cnt", 1.0)))
    res_off, res_on = _run_both(engine, build, accum=("Person", "cnt"))
    _assert_parity(res_off, res_on)


def test_parity_or_composition_degrades_to_no_prune(engine):
    def build():
        return (Query(engine).vertices("Comment")
                .hop("HasCreator", "out",
                     edge_where=gt("creationDate", 20210101) | le("creationDate", 20090101)))
    res_off, res_on = _run_both(engine, build)
    _assert_parity(res_off, res_on)
    assert res_on.pruning["chunks_skipped"] == 0


def test_parity_isin_predicates(engine):
    def build():
        return (Query(engine)
                .vertices("Comment", where=isin("browserUsed", ["Chrome", "Edge"]))
                .hop("HasCreator", "out",
                     source_where=isin("length", list(range(100, 400)))))
    _assert_parity(*_run_both(engine, build))


def test_selective_hop_prunes_and_decodes_less(engine):
    # the acceptance criterion: <=10%-selective edge predicate -> counters > 0
    # and measurably less decode work, with results already parity-checked
    dates = engine.read_vertex_column(
        "Comment", engine.all_vertices("Comment").ids(), "creationDate")
    thr = float(np.quantile(dates, 0.9))

    def build():
        return (Query(engine).vertices("Comment")
                .hop("HasCreator", "out", edge_where=gt("creationDate", thr)))
    res_off, res_on = _run_both(engine, build)
    _assert_parity(res_off, res_on)
    assert res_on.n_edges_scanned <= 0.11 * res_off.pruning["rows_decoded"]
    assert res_on.pruning["chunks_skipped"] > 0
    assert res_on.pruning["rows_pruned"] > 0
    assert res_on.pruning["rows_decoded"] < res_off.pruning["rows_decoded"]
    assert res_on.pruning["bytes_read"] < res_off.pruning["bytes_read"]
    # skipped chunks are never admitted anywhere (no lake fetch either)
    assert (res_on.pruning["chunks_read"] + res_on.pruning["chunks_skipped"]
            >= res_off.pruning["chunks_read"])


# ---------------------------------------------------------------------------
# Predicate.bounds() protocol
# ---------------------------------------------------------------------------

def test_bounds_of_comparisons():
    assert gt("d", 10).bounds()["d"].rejects(0, 10)        # col > 10, max==10
    assert not ge("d", 10).bounds()["d"].rejects(0, 10)    # col >= 10 fits
    assert lt("d", 5).bounds()["d"].rejects(5, 9)
    assert not le("d", 5).bounds()["d"].rejects(5, 9)
    assert eq("d", 7).bounds()["d"].rejects(8, 12)
    assert not eq("d", 7).bounds()["d"].rejects(5, 9)
    assert isin("d", [1, 2, 30]).bounds()["d"].rejects(3, 29)
    assert not isin("d", [1, 2, 30]).bounds()["d"].rejects(3, 30)


def test_bounds_missing_stats_never_reject():
    b = gt("d", 10).bounds()["d"]
    assert not b.rejects(None, None)
    # non-numeric membership candidates cannot be reasoned about
    assert not eq("name", "Music").bounds()["name"].rejects(0, 1)


def test_bounds_and_composition_intersects():
    p = gt("d", 10) & le("d", 20) & gt("x", 3)
    b = p.bounds()
    assert set(b) == {"d", "x"}
    assert b["d"].rejects(0, 10) and b["d"].rejects(21, 99)
    assert not b["d"].rejects(15, 16)
    # AND with an opaque side keeps the boundable side's bounds
    udf = Predicate(lambda f, p_: np.ones(len(f["d"]), dtype=bool), ("d",))
    assert udf.bounds() == {}
    assert (gt("d", 10) & udf).bounds()["d"].rejects(0, 10)


def test_bounds_or_and_ne_degrade():
    assert (gt("d", 10) | le("d", 2)).bounds() == {}
    assert ne("d", 3).bounds() == {}


def test_bounds_unsatisfiable_conjunction_rejects_everything():
    b = (eq("d", 5) & eq("d", 9)).bounds()["d"]   # empty candidate set
    assert b.rejects(0, 100)


def test_bounds_large_isin_uses_envelope():
    b = ColumnBounds(values=frozenset(range(1000, 2000)))
    assert b.rejects(0, 999)
    assert b.rejects(2001, 9999)
    assert not b.rejects(500, 1500)


# ---------------------------------------------------------------------------
# isin vectorization
# ---------------------------------------------------------------------------

def test_isin_numeric_matches_python_loop():
    frame = {"c": np.array([1, 5, 9, 5, 0], dtype=np.int64)}
    np.testing.assert_array_equal(
        isin("c", [5, 0]).evaluate(frame, ""), [False, True, False, True, True])
    np.testing.assert_array_equal(
        isin("c", []).evaluate(frame, ""), np.zeros(5, dtype=bool))
    floats = {"c": np.array([1.5, 2.0, 3.0])}
    np.testing.assert_array_equal(
        isin("c", [2, 3]).evaluate(floats, ""), [False, True, True])


def test_isin_mixed_candidate_types_falls_back_to_loop():
    # a mixed value list coerces np.asarray to strings; the vectorized path
    # must not run there or numeric matches are silently dropped
    frame = {"c": np.array([1, 5, 9], dtype=np.int64)}
    np.testing.assert_array_equal(
        isin("c", [5, "9"]).evaluate(frame, ""), [False, True, False])


def test_isin_object_column_still_works():
    frame = {"c": np.array(["a", "b", "c"], dtype=object)}
    np.testing.assert_array_equal(
        isin("c", ["b", "z"]).evaluate(frame, ""), [False, True, False])


# ---------------------------------------------------------------------------
# read-level zone maps + predicate-aware prefetch
# ---------------------------------------------------------------------------

@pytest.fixture
def topo_cache(tmp_path):
    store = ObjectStore(StoreConfig(root=str(tmp_path / "lake")))
    generate_ldbc(store, scale_factor=0.004, n_files=2, row_group_rows=256)
    topo = GraphTopology(ldbc_graph_schema())
    topo.build(store, LakeCatalog(store))
    return topo, CacheManager(store)


def test_read_edge_columns_pruned_reject_mask(topo_cache):
    topo, cache = topo_cache
    n = topo.n_edges("HasCreator")
    eids = np.arange(n, dtype=np.int64)
    full, rej_none = read_edge_columns_pruned(
        topo, cache, "HasCreator", eids, ["creationDate"])
    assert not rej_none.any()
    thr = float(np.quantile(full["creationDate"], 0.9))
    bounds = gt("creationDate", thr).bounds()
    vals, rej = read_edge_columns_pruned(
        topo, cache, "HasCreator", eids, ["creationDate"], bounds=bounds)
    assert rej.any() and not rej.all()
    # rejects are definitive: every flagged row fails the predicate...
    assert (full["creationDate"][rej] <= thr).all()
    # ...and un-flagged rows carry the true values
    np.testing.assert_array_equal(vals["creationDate"][~rej],
                                  full["creationDate"][~rej])


def test_prefetcher_skips_zone_map_rejected_chunks(topo_cache):
    topo, cache = topo_cache
    n_c = topo.n_vertices("Comment")
    frontier = VSet.full("Comment", n_c)
    pf_plain = Prefetcher(CacheManager(cache.store), topo, pool=None)
    issued_plain = pf_plain.prefetch_edges(frontier, "HasCreator", ["creationDate"])
    bounds = gt("creationDate", 20220101).bounds()
    pf_bound = Prefetcher(CacheManager(cache.store), topo, pool=None)
    issued_bound = pf_bound.prefetch_edges(
        frontier, "HasCreator", ["creationDate"], bounds=bounds)
    assert 0 < issued_bound < issued_plain
    assert pf_bound.stats["pruned_chunks"] > 0
    # vertex side prunes identically (Comment.creationDate is row-clustered)
    pf_v = Prefetcher(CacheManager(cache.store), topo, pool=None)
    issued_v_plain = pf_v.prefetch_vertices(frontier, ["creationDate"])
    pf_v2 = Prefetcher(CacheManager(cache.store), topo, pool=None)
    issued_v_bound = pf_v2.prefetch_vertices(frontier, ["creationDate"], bounds=bounds)
    assert 0 < issued_v_bound < issued_v_plain
